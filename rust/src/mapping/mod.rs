//! Weight mapping: subarray packing, replication planning (Fig. 7), layer →
//! tile layout, and physical placement on the mesh.

pub mod layout;
pub mod placement;
pub mod replication;
pub mod subarray;

pub use layout::{LayerMapping, NetworkMapping};
pub use placement::{Coord, Placement};
pub use replication::{layer_tiles, plan_tiles, validate_plan, ReplicationPlan};
pub use subarray::SubarrayDemand;
