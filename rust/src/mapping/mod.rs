//! Weight mapping: subarray packing behind a backend trait (seed im2col and
//! VW-SDK variable-window packing), replication planning (Fig. 7), layer →
//! tile layout, and physical placement on the mesh.

pub mod backend;
pub mod layout;
pub mod placement;
pub mod replication;
pub mod subarray;

pub use backend::{
    backend_for, pack_layer, Im2col, LayerPacking, MappingBackend, MappingKind, MappingMode,
    MappingSelection, VwSdk,
};
pub use layout::{LayerMapping, NetworkMapping};
pub use placement::{Coord, Placement};
pub use replication::{
    layer_tiles, layer_tiles_with, plan_tiles, plan_tiles_with, validate_plan,
    validate_plan_with, ReplicationPlan,
};
pub use subarray::SubarrayDemand;
