//! Unified parallel scenario-sweep engine (DESIGN.md §1).
//!
//! The paper's headline results are all sweeps: NoC kinds x traffic
//! patterns x injection rates (Figs. 10-11), VGG variants x scenarios x
//! NoCs (Figs. 5, 6, 8, 9), replication budgets (Fig. 7 ablations). This
//! module owns the one executor every bench / example / CLI subcommand
//! uses instead of hand-rolled serial loops:
//!
//! - [`SweepRunner`] — work-stealing parallel map over a point grid
//!   (std threads; input-order results; deterministic).
//! - [`SyntheticSweep`] — the Figs. 10-11 grid over the [`crate::noc`]
//!   backends, with per-point deterministic seeds.
//! - [`point_seed`] — decorrelated per-point RNG seeding so any point can
//!   be re-run in isolation and reproduce exactly.
//!
//! The CNN grid (Figs. 5/6/8/9) plugs in through
//! [`crate::metrics::Grid::run_with`].

pub mod runner;
pub mod synthetic;

pub use runner::SweepRunner;
pub use synthetic::{SyntheticOutcome, SyntheticPoint, SyntheticSweep};

use crate::util::rng::SplitMix64;

/// Derive a deterministic, decorrelated seed for one grid point from a base
/// seed and the point's coordinates. Stable across runs, platforms and
/// thread counts; distinct coordinates give (overwhelmingly) distinct
/// streams via SplitMix64 mixing.
pub fn point_seed(base: u64, coords: &[u64]) -> u64 {
    let mut h = SplitMix64::new(base ^ 0x5EED_0F_5CE_A12E).next_u64();
    for &c in coords {
        h = SplitMix64::new(h ^ c.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64();
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_seed_is_stable_and_sensitive() {
        let a = point_seed(7, &[1, 2, 3]);
        assert_eq!(a, point_seed(7, &[1, 2, 3]));
        assert_ne!(a, point_seed(7, &[1, 2, 4]));
        assert_ne!(a, point_seed(7, &[3, 2, 1]));
        assert_ne!(a, point_seed(8, &[1, 2, 3]));
    }

    #[test]
    fn point_seed_empty_coords_depends_on_base() {
        assert_ne!(point_seed(1, &[]), point_seed(2, &[]));
    }
}
