//! Synthetic-traffic sweep grids (Figs. 10-11 and their descendants): the
//! cartesian product of patterns x injection rates x flow controls, run
//! through the [`SweepRunner`] with per-point deterministic seeding.

use std::time::Instant;

use crate::config::NocKind;
use crate::noc::{run_synthetic_with, AnyTopology, NocStats, Pattern, StepMode, SyntheticConfig};

use super::runner::SweepRunner;
use super::point_seed;

/// One point of a synthetic sweep grid, fully self-contained (the runner
/// hands points to worker threads; everything a worker needs is here).
#[derive(Debug, Clone)]
pub struct SyntheticPoint {
    /// Traffic pattern of this point.
    pub pattern: Pattern,
    /// Injection rate of this point.
    pub rate: f64,
    /// Interconnect evaluated at this point.
    pub kind: NocKind,
    /// Fully-resolved run configuration.
    pub cfg: SyntheticConfig,
    /// Fabric topology and geometry.
    pub topo: AnyTopology,
    /// SMART bypass budget (1 = wormhole).
    pub hpc_max: usize,
}

/// Result of one point: the stats plus the wall-clock the point cost
/// (recorded so benches can track the perf trajectory in BENCH_noc.json).
#[derive(Debug, Clone)]
pub struct SyntheticOutcome {
    /// Pattern of the evaluated point.
    pub pattern: Pattern,
    /// Injection rate of the evaluated point.
    pub rate: f64,
    /// Interconnect of the evaluated point.
    pub kind: NocKind,
    /// Measured statistics.
    pub stats: NocStats,
    /// Wall-clock seconds the point took to simulate.
    pub wall_secs: f64,
}

/// A sweep grid: patterns x rates x kinds over one fabric.
#[derive(Debug, Clone)]
pub struct SyntheticSweep {
    /// Fabric topology and geometry for every point.
    pub topo: AnyTopology,
    /// SMART bypass budget for the smart points.
    pub hpc_max: usize,
    /// Patterns axis of the grid.
    pub patterns: Vec<Pattern>,
    /// Injection-rate axis of the grid.
    pub rates: Vec<f64>,
    /// Interconnect axis of the grid.
    pub kinds: Vec<NocKind>,
    /// Template for every point (pattern / rate / seed overridden per point).
    pub base: SyntheticConfig,
    /// Derive a decorrelated deterministic seed per point from `base.seed`
    /// (recommended); `false` reuses `base.seed` everywhere, which is what
    /// the seed CLI did.
    pub per_point_seeds: bool,
}

impl SyntheticSweep {
    /// The Figs. 10-11 default grid on the given fabric.
    pub fn new(topo: impl Into<AnyTopology>, hpc_max: usize) -> Self {
        Self {
            topo: topo.into(),
            hpc_max,
            patterns: Pattern::ALL.to_vec(),
            rates: vec![0.02, 0.05, 0.08, 0.12, 0.2, 0.3, 0.5, 0.8],
            kinds: vec![NocKind::Wormhole, NocKind::Smart],
            base: SyntheticConfig::default(),
            per_point_seeds: true,
        }
    }

    /// Materialize the grid, pattern-major then rate then kind (the order
    /// every consumer prints in).
    pub fn points(&self) -> Vec<SyntheticPoint> {
        let mut pts = Vec::with_capacity(self.patterns.len() * self.rates.len() * self.kinds.len());
        for (pi, &pattern) in self.patterns.iter().enumerate() {
            for (ri, &rate) in self.rates.iter().enumerate() {
                for (ki, &kind) in self.kinds.iter().enumerate() {
                    let mut cfg = self.base.clone();
                    cfg.pattern = pattern;
                    cfg.injection_rate = rate;
                    if self.per_point_seeds {
                        cfg.seed =
                            point_seed(self.base.seed, &[pi as u64, ri as u64, ki as u64]);
                    }
                    pts.push(SyntheticPoint {
                        pattern,
                        rate,
                        kind,
                        cfg,
                        topo: self.topo,
                        hpc_max: self.hpc_max,
                    });
                }
            }
        }
        pts
    }

    /// Run the whole grid in parallel with the event-driven engine.
    pub fn run(&self, runner: &SweepRunner) -> Vec<SyntheticOutcome> {
        self.run_with_mode(runner, StepMode::EventDriven)
    }

    /// Run the whole grid with an explicit stepping engine (the benches
    /// time the seed cycle-stepped engine against the event-driven one).
    pub fn run_with_mode(&self, runner: &SweepRunner, mode: StepMode) -> Vec<SyntheticOutcome> {
        let points = self.points();
        runner.run(&points, move |_, p| {
            let t0 = Instant::now();
            let stats = run_synthetic_with(p.kind, p.topo, &p.cfg, p.hpc_max, mode);
            SyntheticOutcome {
                pattern: p.pattern,
                rate: p.rate,
                kind: p.kind,
                stats,
                wall_secs: t0.elapsed().as_secs_f64(),
            }
        })
    }

    /// Outcomes for one pattern, in rate-major order (a Fig. 10/11 table).
    pub fn rows_for<'a>(
        &self,
        outcomes: &'a [SyntheticOutcome],
        pattern: Pattern,
    ) -> Vec<&'a SyntheticOutcome> {
        outcomes.iter().filter(|o| o.pattern == pattern).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SyntheticSweep {
        use crate::noc::Mesh;
        let mut s = SyntheticSweep::new(Mesh::new(4, 4), 6);
        s.patterns = vec![Pattern::UniformRandom, Pattern::Transpose];
        s.rates = vec![0.02, 0.05];
        s.kinds = vec![NocKind::Wormhole, NocKind::Smart, NocKind::Ideal];
        s.base.warmup = 100;
        s.base.measure = 400;
        s.base.drain = 2_000;
        s
    }

    #[test]
    fn grid_has_full_product() {
        let s = tiny();
        assert_eq!(s.points().len(), 2 * 2 * 3);
    }

    #[test]
    fn per_point_seeds_are_distinct_and_stable() {
        let s = tiny();
        let a = s.points();
        let b = s.points();
        let seeds: Vec<u64> = a.iter().map(|p| p.cfg.seed).collect();
        assert_eq!(seeds, b.iter().map(|p| p.cfg.seed).collect::<Vec<_>>());
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "seed collision in {seeds:?}");
    }

    #[test]
    fn parallel_sweep_is_deterministic() {
        let s = tiny();
        let a = s.run(&SweepRunner::with_threads(1));
        let b = s.run(&SweepRunner::with_threads(4));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.stats, y.stats, "{:?}/{}", x.kind, x.pattern.name());
        }
    }

    #[test]
    fn rows_filter_by_pattern() {
        let s = tiny();
        let out = s.run(&SweepRunner::with_threads(2));
        let rows = s.rows_for(&out, Pattern::Transpose);
        assert_eq!(rows.len(), 2 * 3);
        assert!(rows.iter().all(|o| o.pattern == Pattern::Transpose));
    }
}
