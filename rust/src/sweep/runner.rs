//! The parallel scenario-sweep executor.
//!
//! Every figure of the paper is a sweep — NoC kinds x traffic patterns x
//! injection rates x VGG variants x scenarios — and the seed code-base
//! hand-rolled a serial loop per caller. [`SweepRunner`] is the one
//! executor: it fans a grid of points out across OS threads with
//! work-stealing (an atomic cursor over the point list; `std::thread::scope`
//! because the offline vendored crate set has no `rayon` — DESIGN.md §1,
//! substitution 4) and returns results in input order, so output is
//! deterministic regardless of scheduling.
//!
//! Determinism contract: the point function must derive all randomness from
//! the point itself (see [`super::point_seed`]), never from shared state.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Parallel map over a sweep grid.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    threads: usize,
}

impl Default for SweepRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepRunner {
    /// A runner sized to the machine (`SMART_PIM_SWEEP_THREADS` overrides).
    pub fn new() -> Self {
        Self {
            threads: default_threads(),
        }
    }

    /// A runner with an explicit worker count (1 = serial, useful for
    /// baseline timing and debugging).
    pub fn with_threads(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
        }
    }

    /// Worker-thread count this runner fans out to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f(index, point)` for every point, in parallel, returning
    /// results in input order. `f` runs on worker threads: it must not
    /// touch thread-local or global mutable state.
    pub fn run<P, R, F>(&self, points: &[P], f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        F: Fn(usize, &P) -> R + Sync,
    {
        let n = points.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            return points
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let _prof = crate::obs::profile::scope("sweep.point");
                    f(i, p)
                })
                .collect();
        }
        // Work stealing: a shared cursor; each worker grabs the next
        // unclaimed index. Long points therefore never gate short ones the
        // way a static block partition would.
        let cursor = AtomicUsize::new(0);
        let collected: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = {
                            let _prof = crate::obs::profile::scope("sweep.point");
                            f(i, &points[i])
                        };
                        local.push((i, r));
                    }
                    if !local.is_empty() {
                        collected.lock().unwrap().extend(local);
                    }
                });
            }
        });
        let mut pairs = collected.into_inner().unwrap();
        debug_assert_eq!(pairs.len(), n);
        pairs.sort_by_key(|&(i, _)| i);
        pairs.into_iter().map(|(_, r)| r).collect()
    }
}

fn default_threads() -> usize {
    if let Some(n) = std::env::var("SMART_PIM_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_preserve_input_order() {
        let points: Vec<u64> = (0..257).collect();
        let runner = SweepRunner::with_threads(8);
        let out = runner.run(&points, |i, &p| {
            assert_eq!(i as u64, p);
            p * p
        });
        let want: Vec<u64> = points.iter().map(|p| p * p).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn serial_and_parallel_agree() {
        let points: Vec<u64> = (0..64).collect();
        let f = |_: usize, &p: &u64| p.wrapping_mul(0x9E37_79B9).rotate_left(13);
        let serial = SweepRunner::with_threads(1).run(&points, f);
        let parallel = SweepRunner::with_threads(7).run(&points, f);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn empty_grid_is_fine() {
        let runner = SweepRunner::new();
        let out: Vec<u32> = runner.run(&[] as &[u8], |_, _| 1u32);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_is_stolen() {
        // One huge point among many tiny ones: all results still arrive,
        // in order, from a pool smaller than the grid.
        let points: Vec<u64> = (0..40).collect();
        let runner = SweepRunner::with_threads(4);
        let out = runner.run(&points, |_, &p| {
            if p == 0 {
                // Busy work: a deterministic pseudo-load.
                let mut x = 1u64;
                for i in 0..200_000u64 {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
                }
                (x & 1) + p
            } else {
                p
            }
        });
        assert_eq!(out.len(), 40);
        assert_eq!(&out[1..], &points[1..]);
    }

    #[test]
    fn threads_clamped_to_one() {
        assert_eq!(SweepRunner::with_threads(0).threads(), 1);
    }
}
