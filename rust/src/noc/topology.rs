//! 2D mesh topology (Sec. V: "the NoC is a 16x20 2D mesh"; the synthetic
//! traffic study uses 8x8).

/// Output/input port directions of a mesh router. `Local` is the
/// injection/ejection port to the tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    /// Toward larger x.
    East,
    /// Toward smaller x.
    West,
    /// Toward larger y.
    North,
    /// Toward smaller y.
    South,
    /// The node's own inject/eject port.
    Local,
}

impl Dir {
    /// The four mesh directions (no `Local`).
    pub const SIDES: [Dir; 4] = [Dir::East, Dir::West, Dir::North, Dir::South];

    /// Dense index (East..Local = 0..4) for port arrays.
    pub fn index(self) -> usize {
        match self {
            Dir::East => 0,
            Dir::West => 1,
            Dir::North => 2,
            Dir::South => 3,
            Dir::Local => 4,
        }
    }

    /// The reverse direction (east <-> west, north <-> south).
    pub fn opposite(self) -> Dir {
        match self {
            Dir::East => Dir::West,
            Dir::West => Dir::East,
            Dir::North => Dir::South,
            Dir::South => Dir::North,
            Dir::Local => Dir::Local,
        }
    }
}

/// A `w x h` mesh; node id = `y * w + x`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    /// Width in nodes.
    pub w: usize,
    /// Height in nodes.
    pub h: usize,
}

impl Mesh {
    /// A `w x h` mesh.
    pub fn new(w: usize, h: usize) -> Self {
        assert!(w > 0 && h > 0);
        Self { w, h }
    }

    /// Total node count.
    pub fn nodes(&self) -> usize {
        self.w * self.h
    }

    /// (x, y) of a node id.
    pub fn xy(&self, node: usize) -> (usize, usize) {
        (node % self.w, node / self.w)
    }

    /// Node id at (x, y).
    pub fn id(&self, x: usize, y: usize) -> usize {
        debug_assert!(x < self.w && y < self.h);
        y * self.w + x
    }

    /// Neighbor in direction `d`, or `None` at the mesh edge.
    pub fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        let (x, y) = self.xy(node);
        match d {
            Dir::East if x + 1 < self.w => Some(self.id(x + 1, y)),
            Dir::West if x > 0 => Some(self.id(x - 1, y)),
            Dir::South if y + 1 < self.h => Some(self.id(x, y + 1)),
            Dir::North if y > 0 => Some(self.id(x, y - 1)),
            _ => None,
        }
    }

    /// XY dimension-ordered routing: next direction from `node` toward
    /// `dst` (X first, then Y). `Local` when already there.
    pub fn xy_route(&self, node: usize, dst: usize) -> Dir {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x < dx {
            Dir::East
        } else if x > dx {
            Dir::West
        } else if y < dy {
            Dir::South
        } else if y > dy {
            Dir::North
        } else {
            Dir::Local
        }
    }

    /// Minimal hop count under XY routing (Manhattan distance).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.xy(a);
        let (bx, by) = self.xy(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Straight-run length from `node` toward `dst` along the current XY
    /// routing dimension (how far a SMART bypass could go before a turn or
    /// the destination).
    pub fn straight_run(&self, node: usize, dst: usize) -> usize {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x != dx {
            x.abs_diff(dx)
        } else {
            y.abs_diff(dy)
        }
    }

    /// Directed link id for `node` -> neighbor in `d` (d must be a side).
    pub fn link_id(&self, node: usize, d: Dir) -> usize {
        node * 4 + d.index()
    }

    /// Directed link count of the mesh.
    pub fn n_links(&self) -> usize {
        self.nodes() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_respect_edges() {
        let m = Mesh::new(4, 3);
        assert_eq!(m.neighbor(0, Dir::West), None);
        assert_eq!(m.neighbor(0, Dir::North), None);
        assert_eq!(m.neighbor(0, Dir::East), Some(1));
        assert_eq!(m.neighbor(0, Dir::South), Some(4));
        assert_eq!(m.neighbor(11, Dir::East), None);
        assert_eq!(m.neighbor(11, Dir::South), None);
    }

    #[test]
    fn xy_route_goes_x_first() {
        let m = Mesh::new(8, 8);
        let src = m.id(1, 1);
        let dst = m.id(5, 6);
        assert_eq!(m.xy_route(src, dst), Dir::East);
        let aligned = m.id(5, 1);
        assert_eq!(m.xy_route(aligned, dst), Dir::South);
        assert_eq!(m.xy_route(dst, dst), Dir::Local);
    }

    #[test]
    fn xy_route_reaches_destination() {
        // Property: following xy_route always terminates at dst in exactly
        // `hops` steps.
        let m = Mesh::new(6, 5);
        for src in 0..m.nodes() {
            for dst in 0..m.nodes() {
                let mut at = src;
                let mut steps = 0;
                while at != dst {
                    let d = m.xy_route(at, dst);
                    at = m.neighbor(at, d).expect("route must stay in mesh");
                    steps += 1;
                    assert!(steps <= m.hops(src, dst), "non-minimal route");
                }
                assert_eq!(steps, m.hops(src, dst));
            }
        }
    }

    #[test]
    fn straight_run_lengths() {
        let m = Mesh::new(8, 8);
        assert_eq!(m.straight_run(m.id(0, 0), m.id(5, 3)), 5); // X first
        assert_eq!(m.straight_run(m.id(5, 0), m.id(5, 3)), 3); // then Y
        assert_eq!(m.straight_run(m.id(5, 3), m.id(5, 3)), 0);
    }

    #[test]
    fn link_ids_unique() {
        let m = Mesh::new(4, 4);
        let mut seen = std::collections::HashSet::new();
        for n in 0..m.nodes() {
            for d in Dir::SIDES {
                assert!(seen.insert(m.link_id(n, d)));
            }
        }
        assert_eq!(seen.len(), m.n_links());
    }

    #[test]
    fn opposite_involutive() {
        for d in Dir::SIDES {
            assert_eq!(d.opposite().opposite(), d);
            assert_ne!(d.opposite(), d);
        }
    }
}
