//! The topology layer: geometry/routing contracts behind the flit engine.
//!
//! [`Topology`] abstracts everything the SMART/wormhole engine in
//! [`super::network`] asks of the fabric — node count, port neighbors,
//! minimal-route next hops, hop distances, straight-run lengths for SMART
//! segment planning, and link enumeration for energy accounting. Three
//! implementations ship: [`Mesh2D`] (Sec. V: "the NoC is a 16x20 2D
//! mesh"; the synthetic study uses 8x8 — bit-identical to the pre-trait
//! code, and what the [`Mesh`] alias still names), [`Torus2D`] (wrap
//! links, shortest-direction XY routing), and [`PrismCnn`] (a
//! chain-with-stride pipeline fabric in the spirit of the Parallel-Prism
//! topology of arxiv 1906.03474). [`AnyTopology`] is the `Copy` carrier
//! the engine and sweep workers hold.
//!
//! Every implementation must satisfy the engine's routing contract
//! (checked exhaustively by `check_contract` below and the
//! `golden_topology` integration suite):
//!
//! - **minimality:** stepping `route(at, dst)` reduces `hops(at, dst)` by
//!   exactly 1 and reaches `dst`;
//! - **prefix consistency:** for any straight run a head can take (start
//!   `a`, direction `d`, length `straight_run(a, dst)`), every prefix node
//!   `m_i` routes to every later prefix node `m_k` with direction `d` and
//!   `hops == k - i` — body flits replay head stop lists relying on this;
//! - **opposite symmetry:** `neighbor(a, d) == b` implies
//!   `neighbor(b, d.opposite()) == a` (flits land in the `d.opposite()`
//!   input buffer);
//! - **no edges on routes:** `neighbor` is `Some` along minimal routes,
//!   and `straight_run >= 1` whenever the node is not the destination.

use crate::config::TopologyKind;

/// Output/input port directions of a router. `Local` is the
/// injection/ejection port to the tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    /// Toward larger x (next chain position on the prism).
    East,
    /// Toward smaller x (previous chain position on the prism).
    West,
    /// Toward smaller y (stride `-w` on the prism).
    North,
    /// Toward larger y (stride `+w` on the prism).
    South,
    /// The node's own inject/eject port.
    Local,
}

impl Dir {
    /// The four side directions (no `Local`).
    pub const SIDES: [Dir; 4] = [Dir::East, Dir::West, Dir::North, Dir::South];

    /// Dense index (East..Local = 0..4) for port arrays.
    pub fn index(self) -> usize {
        match self {
            Dir::East => 0,
            Dir::West => 1,
            Dir::North => 2,
            Dir::South => 3,
            Dir::Local => 4,
        }
    }

    /// The reverse direction (east <-> west, north <-> south).
    pub fn opposite(self) -> Dir {
        match self {
            Dir::East => Dir::West,
            Dir::West => Dir::East,
            Dir::North => Dir::South,
            Dir::South => Dir::North,
            Dir::Local => Dir::Local,
        }
    }
}

/// Everything the flit engine, placement pass, and energy model ask of a
/// fabric. See the module doc for the routing contract implementations
/// must uphold.
pub trait Topology {
    /// Total node count.
    fn nodes(&self) -> usize;

    /// (width, height) of the underlying node grid — every shipped
    /// topology arranges its `nodes()` ids on a `w x h` grid, which the
    /// synthetic traffic patterns use as their coordinate map.
    fn dims(&self) -> (usize, usize);

    /// Neighbor in direction `d`, or `None` off the fabric edge.
    fn neighbor(&self, node: usize, d: Dir) -> Option<usize>;

    /// Minimal-route next direction from `node` toward `dst` (`Local`
    /// when already there).
    fn route(&self, node: usize, dst: usize) -> Dir;

    /// Minimal hop count from `a` to `b`.
    fn hops(&self, a: usize, b: usize) -> usize;

    /// Straight-run length from `node` toward `dst` along the current
    /// routing direction (how far a SMART bypass could go before a turn
    /// or the destination).
    fn straight_run(&self, node: usize, dst: usize) -> usize;

    /// Directed link id for `node` -> neighbor in `d` (d must be a side);
    /// indexes the engine's link-allocation stamps and the energy model's
    /// per-link ledger.
    fn link_id(&self, node: usize, d: Dir) -> usize {
        node * 4 + d.index()
    }

    /// Directed link count (4 per node; edge ports count too so ids stay
    /// dense and stable across topologies).
    fn n_links(&self) -> usize {
        self.nodes() * 4
    }
}

/// A `w x h` 2D mesh; node id = `y * w + x`. XY dimension-order routing,
/// no wrap links — the paper's fabric, unchanged from the pre-trait code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh2D {
    /// Width in nodes.
    pub w: usize,
    /// Height in nodes.
    pub h: usize,
}

/// The topology the whole pre-trait stack was written against; kept as an
/// alias so existing call sites (and their goldens) are untouched.
pub type Mesh = Mesh2D;

impl Mesh2D {
    /// A `w x h` mesh.
    pub fn new(w: usize, h: usize) -> Self {
        assert!(w > 0 && h > 0);
        Self { w, h }
    }

    /// Total node count.
    pub fn nodes(&self) -> usize {
        self.w * self.h
    }

    /// (x, y) of a node id.
    pub fn xy(&self, node: usize) -> (usize, usize) {
        (node % self.w, node / self.w)
    }

    /// Node id at (x, y).
    pub fn id(&self, x: usize, y: usize) -> usize {
        debug_assert!(x < self.w && y < self.h);
        y * self.w + x
    }

    /// Neighbor in direction `d`, or `None` at the mesh edge.
    pub fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        let (x, y) = self.xy(node);
        match d {
            Dir::East if x + 1 < self.w => Some(self.id(x + 1, y)),
            Dir::West if x > 0 => Some(self.id(x - 1, y)),
            Dir::South if y + 1 < self.h => Some(self.id(x, y + 1)),
            Dir::North if y > 0 => Some(self.id(x, y - 1)),
            _ => None,
        }
    }

    /// XY dimension-ordered routing: next direction from `node` toward
    /// `dst` (X first, then Y). `Local` when already there.
    pub fn xy_route(&self, node: usize, dst: usize) -> Dir {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x < dx {
            Dir::East
        } else if x > dx {
            Dir::West
        } else if y < dy {
            Dir::South
        } else if y > dy {
            Dir::North
        } else {
            Dir::Local
        }
    }

    /// Minimal hop count under XY routing (Manhattan distance).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.xy(a);
        let (bx, by) = self.xy(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Straight-run length from `node` toward `dst` along the current XY
    /// routing dimension (how far a SMART bypass could go before a turn or
    /// the destination).
    pub fn straight_run(&self, node: usize, dst: usize) -> usize {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x != dx {
            x.abs_diff(dx)
        } else {
            y.abs_diff(dy)
        }
    }

    /// Directed link id for `node` -> neighbor in `d` (d must be a side).
    pub fn link_id(&self, node: usize, d: Dir) -> usize {
        node * 4 + d.index()
    }

    /// Directed link count of the mesh.
    pub fn n_links(&self) -> usize {
        self.nodes() * 4
    }
}

impl Topology for Mesh2D {
    fn nodes(&self) -> usize {
        Mesh2D::nodes(self)
    }

    fn dims(&self) -> (usize, usize) {
        (self.w, self.h)
    }

    fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        Mesh2D::neighbor(self, node, d)
    }

    fn route(&self, node: usize, dst: usize) -> Dir {
        self.xy_route(node, dst)
    }

    fn hops(&self, a: usize, b: usize) -> usize {
        Mesh2D::hops(self, a, b)
    }

    fn straight_run(&self, node: usize, dst: usize) -> usize {
        Mesh2D::straight_run(self, node, dst)
    }
}

/// A `w x h` 2D torus: the mesh plus wrap links, routed
/// shortest-direction per dimension (ties break East / South so routes
/// stay deterministic). Wrap halves the worst-case dimension distance, so
/// straight runs shorten but hop counts drop fabric-wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Torus2D {
    /// Width in nodes.
    pub w: usize,
    /// Height in nodes.
    pub h: usize,
}

impl Torus2D {
    /// A `w x h` torus.
    pub fn new(w: usize, h: usize) -> Self {
        assert!(w > 0 && h > 0);
        Self { w, h }
    }

    fn xy(&self, node: usize) -> (usize, usize) {
        (node % self.w, node / self.w)
    }
}

impl Topology for Torus2D {
    fn nodes(&self) -> usize {
        self.w * self.h
    }

    fn dims(&self) -> (usize, usize) {
        (self.w, self.h)
    }

    fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        let (x, y) = self.xy(node);
        match d {
            // A 1-wide axis would make the wrap link a self-loop; suppress
            // it (routing never asks for that axis then).
            Dir::East if self.w > 1 => Some(y * self.w + (x + 1) % self.w),
            Dir::West if self.w > 1 => Some(y * self.w + (x + self.w - 1) % self.w),
            Dir::South if self.h > 1 => Some(((y + 1) % self.h) * self.w + x),
            Dir::North if self.h > 1 => Some(((y + self.h - 1) % self.h) * self.w + x),
            _ => None,
        }
    }

    fn route(&self, node: usize, dst: usize) -> Dir {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x != dx {
            let east = (dx + self.w - x) % self.w;
            let west = (x + self.w - dx) % self.w;
            if east <= west {
                Dir::East
            } else {
                Dir::West
            }
        } else if y != dy {
            let south = (dy + self.h - y) % self.h;
            let north = (y + self.h - dy) % self.h;
            if south <= north {
                Dir::South
            } else {
                Dir::North
            }
        } else {
            Dir::Local
        }
    }

    fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.xy(a);
        let (bx, by) = self.xy(b);
        let hx = ((bx + self.w - ax) % self.w).min((ax + self.w - bx) % self.w);
        let hy = ((by + self.h - ay) % self.h).min((ay + self.h - by) % self.h);
        hx + hy
    }

    fn straight_run(&self, node: usize, dst: usize) -> usize {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x != dx {
            ((dx + self.w - x) % self.w).min((x + self.w - dx) % self.w)
        } else {
            ((dy + self.h - y) % self.h).min((y + self.h - dy) % self.h)
        }
    }
}

/// Chain-with-stride pipeline fabric in the spirit of Parallel Prism
/// (arxiv 1906.03474): node ids are pipeline (layer-stage chain)
/// positions. East/West are dedicated forward/backward unit links along
/// the chain — unlike a mesh they also bridge row ends, so
/// pipeline-adjacent stages are always one hop apart — and South/North
/// are stride-`w` express links. Routing is stride-first with a bounded
/// overshoot (one extra stride plus a short backtrack beats a long unit
/// walk when strictly cheaper and still on-chip), which keeps every route
/// minimal and prefix-consistent for SMART segment replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrismCnn {
    /// Express-link stride (chain positions per "row").
    pub w: usize,
    /// Rows (chain length = `w * h`).
    pub h: usize,
}

/// One resolved prism route: stride phase then unit phase.
struct PrismPlan {
    stride_dir: Dir,
    stride_len: usize,
    unit_dir: Dir,
    unit_len: usize,
}

impl PrismCnn {
    /// A prism over a `w * h`-stage chain with stride-`w` express links.
    pub fn new(w: usize, h: usize) -> Self {
        assert!(w > 0 && h > 0);
        Self { w, h }
    }

    /// Stride-first minimal plan from `node` to `dst`. The overshoot
    /// branch is taken only when strictly cheaper, so the preferred
    /// option is invariant along the route (each stride reduces both
    /// options' costs by 1) — the prefix-consistency proof the engine's
    /// stop-list replay needs.
    fn plan(&self, node: usize, dst: usize) -> PrismPlan {
        let (w, last) = (self.w, self.w * self.h - 1);
        if node == dst {
            return PrismPlan {
                stride_dir: Dir::Local,
                stride_len: 0,
                unit_dir: Dir::Local,
                unit_len: 0,
            };
        }
        if dst > node {
            let d = dst - node;
            let (q, r) = (d / w, d % w);
            let overshoot_ok = r > 0 && node + (q + 1) * w <= last;
            if overshoot_ok && q + 1 + (w - r) < q + r {
                PrismPlan {
                    stride_dir: Dir::South,
                    stride_len: q + 1,
                    unit_dir: Dir::West,
                    unit_len: w - r,
                }
            } else {
                PrismPlan {
                    stride_dir: Dir::South,
                    stride_len: q,
                    unit_dir: Dir::East,
                    unit_len: r,
                }
            }
        } else {
            let d = node - dst;
            let (q, r) = (d / w, d % w);
            let overshoot_ok = r > 0 && node >= (q + 1) * w;
            if overshoot_ok && q + 1 + (w - r) < q + r {
                PrismPlan {
                    stride_dir: Dir::North,
                    stride_len: q + 1,
                    unit_dir: Dir::East,
                    unit_len: w - r,
                }
            } else {
                PrismPlan {
                    stride_dir: Dir::North,
                    stride_len: q,
                    unit_dir: Dir::West,
                    unit_len: r,
                }
            }
        }
    }
}

impl Topology for PrismCnn {
    fn nodes(&self) -> usize {
        self.w * self.h
    }

    fn dims(&self) -> (usize, usize) {
        (self.w, self.h)
    }

    fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        let last = self.nodes() - 1;
        match d {
            Dir::East if node + 1 <= last => Some(node + 1),
            Dir::West if node >= 1 => Some(node - 1),
            Dir::South if node + self.w <= last => Some(node + self.w),
            Dir::North if node >= self.w => Some(node - self.w),
            _ => None,
        }
    }

    fn route(&self, node: usize, dst: usize) -> Dir {
        let p = self.plan(node, dst);
        if p.stride_len > 0 {
            p.stride_dir
        } else if p.unit_len > 0 {
            p.unit_dir
        } else {
            Dir::Local
        }
    }

    fn hops(&self, a: usize, b: usize) -> usize {
        let p = self.plan(a, b);
        p.stride_len + p.unit_len
    }

    fn straight_run(&self, node: usize, dst: usize) -> usize {
        let p = self.plan(node, dst);
        if p.stride_len > 0 {
            p.stride_len
        } else {
            p.unit_len
        }
    }
}

/// The `Copy` topology carrier the engine, sweep workers, and config
/// resolution hold (a trait object would cost a `Box` + vtable dispatch
/// on the per-flit hot path and break the by-value `SweepRunner`
/// workers). Inherent methods mirror the [`Topology`] trait so call sites
/// need no trait import.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnyTopology {
    /// The paper's 2D mesh (the default; bit-identical to pre-trait code).
    Mesh(Mesh2D),
    /// 2D torus with wrap links.
    Torus(Torus2D),
    /// Parallel-Prism-style chain-with-stride pipeline fabric.
    Prism(PrismCnn),
}

impl AnyTopology {
    /// Build the `kind` topology over a `w x h` node grid.
    pub fn new(kind: TopologyKind, w: usize, h: usize) -> Self {
        match kind {
            TopologyKind::Mesh => AnyTopology::Mesh(Mesh2D::new(w, h)),
            TopologyKind::Torus => AnyTopology::Torus(Torus2D::new(w, h)),
            TopologyKind::Prism => AnyTopology::Prism(PrismCnn::new(w, h)),
        }
    }

    /// The configured topology over a node's tile grid.
    pub fn for_node(arch: &crate::config::ArchConfig) -> Self {
        Self::new(arch.topology, arch.tiles_x, arch.tiles_y)
    }

    /// Which topology family this is.
    pub fn kind(&self) -> TopologyKind {
        match self {
            AnyTopology::Mesh(_) => TopologyKind::Mesh,
            AnyTopology::Torus(_) => TopologyKind::Torus,
            AnyTopology::Prism(_) => TopologyKind::Prism,
        }
    }

    /// Total node count.
    pub fn nodes(&self) -> usize {
        match self {
            AnyTopology::Mesh(t) => Mesh2D::nodes(t),
            AnyTopology::Torus(t) => Topology::nodes(t),
            AnyTopology::Prism(t) => Topology::nodes(t),
        }
    }

    /// (width, height) of the node grid.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            AnyTopology::Mesh(t) => (t.w, t.h),
            AnyTopology::Torus(t) => (t.w, t.h),
            AnyTopology::Prism(t) => (t.w, t.h),
        }
    }

    /// Neighbor in direction `d`, or `None` off the fabric edge.
    pub fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        match self {
            AnyTopology::Mesh(t) => Mesh2D::neighbor(t, node, d),
            AnyTopology::Torus(t) => Topology::neighbor(t, node, d),
            AnyTopology::Prism(t) => Topology::neighbor(t, node, d),
        }
    }

    /// Minimal-route next direction from `node` toward `dst`.
    pub fn route(&self, node: usize, dst: usize) -> Dir {
        match self {
            AnyTopology::Mesh(t) => t.xy_route(node, dst),
            AnyTopology::Torus(t) => Topology::route(t, node, dst),
            AnyTopology::Prism(t) => Topology::route(t, node, dst),
        }
    }

    /// Minimal hop count from `a` to `b`.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        match self {
            AnyTopology::Mesh(t) => Mesh2D::hops(t, a, b),
            AnyTopology::Torus(t) => Topology::hops(t, a, b),
            AnyTopology::Prism(t) => Topology::hops(t, a, b),
        }
    }

    /// Straight-run length from `node` toward `dst`.
    pub fn straight_run(&self, node: usize, dst: usize) -> usize {
        match self {
            AnyTopology::Mesh(t) => Mesh2D::straight_run(t, node, dst),
            AnyTopology::Torus(t) => Topology::straight_run(t, node, dst),
            AnyTopology::Prism(t) => Topology::straight_run(t, node, dst),
        }
    }

    /// Directed link id for `node` -> neighbor in `d` (d must be a side).
    pub fn link_id(&self, node: usize, d: Dir) -> usize {
        node * 4 + d.index()
    }

    /// Directed link count of the fabric.
    pub fn n_links(&self) -> usize {
        self.nodes() * 4
    }
}

impl Topology for AnyTopology {
    fn nodes(&self) -> usize {
        AnyTopology::nodes(self)
    }

    fn dims(&self) -> (usize, usize) {
        AnyTopology::dims(self)
    }

    fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        AnyTopology::neighbor(self, node, d)
    }

    fn route(&self, node: usize, dst: usize) -> Dir {
        AnyTopology::route(self, node, dst)
    }

    fn hops(&self, a: usize, b: usize) -> usize {
        AnyTopology::hops(self, a, b)
    }

    fn straight_run(&self, node: usize, dst: usize) -> usize {
        AnyTopology::straight_run(self, node, dst)
    }
}

impl From<Mesh2D> for AnyTopology {
    fn from(t: Mesh2D) -> Self {
        AnyTopology::Mesh(t)
    }
}

impl From<Torus2D> for AnyTopology {
    fn from(t: Torus2D) -> Self {
        AnyTopology::Torus(t)
    }
}

impl From<PrismCnn> for AnyTopology {
    fn from(t: PrismCnn) -> Self {
        AnyTopology::Prism(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_respect_edges() {
        let m = Mesh::new(4, 3);
        assert_eq!(m.neighbor(0, Dir::West), None);
        assert_eq!(m.neighbor(0, Dir::North), None);
        assert_eq!(m.neighbor(0, Dir::East), Some(1));
        assert_eq!(m.neighbor(0, Dir::South), Some(4));
        assert_eq!(m.neighbor(11, Dir::East), None);
        assert_eq!(m.neighbor(11, Dir::South), None);
    }

    #[test]
    fn xy_route_goes_x_first() {
        let m = Mesh::new(8, 8);
        let src = m.id(1, 1);
        let dst = m.id(5, 6);
        assert_eq!(m.xy_route(src, dst), Dir::East);
        let aligned = m.id(5, 1);
        assert_eq!(m.xy_route(aligned, dst), Dir::South);
        assert_eq!(m.xy_route(dst, dst), Dir::Local);
    }

    #[test]
    fn xy_route_reaches_destination() {
        // Property: following xy_route always terminates at dst in exactly
        // `hops` steps.
        let m = Mesh::new(6, 5);
        for src in 0..m.nodes() {
            for dst in 0..m.nodes() {
                let mut at = src;
                let mut steps = 0;
                while at != dst {
                    let d = m.xy_route(at, dst);
                    at = m.neighbor(at, d).expect("route must stay in mesh");
                    steps += 1;
                    assert!(steps <= m.hops(src, dst), "non-minimal route");
                }
                assert_eq!(steps, m.hops(src, dst));
            }
        }
    }

    #[test]
    fn straight_run_lengths() {
        let m = Mesh::new(8, 8);
        assert_eq!(m.straight_run(m.id(0, 0), m.id(5, 3)), 5); // X first
        assert_eq!(m.straight_run(m.id(5, 0), m.id(5, 3)), 3); // then Y
        assert_eq!(m.straight_run(m.id(5, 3), m.id(5, 3)), 0);
    }

    #[test]
    fn link_ids_unique() {
        let m = Mesh::new(4, 4);
        let mut seen = std::collections::HashSet::new();
        for n in 0..m.nodes() {
            for d in Dir::SIDES {
                assert!(seen.insert(m.link_id(n, d)));
            }
        }
        assert_eq!(seen.len(), m.n_links());
    }

    #[test]
    fn opposite_involutive() {
        for d in Dir::SIDES {
            assert_eq!(d.opposite().opposite(), d);
            assert_ne!(d.opposite(), d);
        }
    }

    /// Exhaustive engine-contract check shared by all three topologies:
    /// opposite symmetry, route minimality/progress, and straight-run
    /// prefix consistency (what body-flit stop-list replay relies on).
    fn check_contract(t: &AnyTopology) {
        let n = t.nodes();
        for a in 0..n {
            for d in Dir::SIDES {
                if let Some(b) = t.neighbor(a, d) {
                    assert_eq!(t.neighbor(b, d.opposite()), Some(a), "{a} {d:?}");
                }
            }
        }
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    assert_eq!(t.route(src, dst), Dir::Local);
                    assert_eq!(t.hops(src, dst), 0);
                    continue;
                }
                let mut at = src;
                let mut steps = 0;
                while at != dst {
                    let d = t.route(at, dst);
                    let run = t.straight_run(at, dst);
                    assert!((1..=64).contains(&run), "run {run} at {at}->{dst}");
                    let h0 = t.hops(at, dst);
                    let mut chain = vec![at];
                    for _ in 0..run {
                        let tail = *chain.last().unwrap();
                        chain.push(t.neighbor(tail, d).expect("edge on route"));
                    }
                    for k in 1..=run {
                        for i in 0..k {
                            assert_eq!(t.route(chain[i], chain[k]), d, "seg {chain:?}");
                            assert_eq!(t.hops(chain[i], chain[k]), k - i, "seg {chain:?}");
                        }
                        assert_eq!(t.hops(chain[k], dst), h0 - k, "minimality {chain:?}");
                    }
                    at = chain[1];
                    steps += 1;
                    assert!(steps <= 4 * n, "runaway route {src}->{dst}");
                }
                assert_eq!(steps, t.hops(src, dst));
            }
        }
    }

    #[test]
    fn mesh_satisfies_engine_contract() {
        check_contract(&AnyTopology::new(TopologyKind::Mesh, 5, 4));
    }

    #[test]
    fn torus_satisfies_engine_contract() {
        check_contract(&AnyTopology::new(TopologyKind::Torus, 5, 4));
        check_contract(&AnyTopology::new(TopologyKind::Torus, 2, 2));
        check_contract(&AnyTopology::new(TopologyKind::Torus, 1, 6));
    }

    #[test]
    fn prism_satisfies_engine_contract() {
        check_contract(&AnyTopology::new(TopologyKind::Prism, 5, 4));
        check_contract(&AnyTopology::new(TopologyKind::Prism, 4, 4));
        check_contract(&AnyTopology::new(TopologyKind::Prism, 1, 6));
    }

    #[test]
    fn torus_wraps_and_shortens() {
        let t = AnyTopology::new(TopologyKind::Torus, 8, 8);
        let m = Mesh::new(8, 8);
        // Corner to corner: the mesh walks 14, the torus wraps in 2.
        assert_eq!(t.hops(0, 63), 2);
        assert_eq!(m.hops(0, 63), 14);
        assert_eq!(t.neighbor(0, Dir::West), Some(7));
        assert_eq!(t.neighbor(0, Dir::North), Some(56));
    }

    #[test]
    fn prism_chain_neighbors_bridge_rows() {
        let p = AnyTopology::new(TopologyKind::Prism, 4, 4);
        // End of row 0 to start of row 1: one forward chain hop (the mesh
        // under row-major ids walks the whole row back).
        assert_eq!(p.neighbor(3, Dir::East), Some(4));
        assert_eq!(p.hops(3, 4), 1);
        assert_eq!(Mesh::new(4, 4).hops(3, 4), 4);
        // Express stride link.
        assert_eq!(p.neighbor(1, Dir::South), Some(5));
        // Overshoot: 0 -> 3 rides the stride then backtracks (2 < 3).
        assert_eq!(p.hops(0, 3), 2);
        assert_eq!(p.route(0, 3), Dir::South);
    }

    #[test]
    fn mesh_alias_is_mesh2d() {
        // The alias keeps the whole pre-trait API surface compiling and
        // the carrier agreeing with it.
        let m: Mesh = Mesh2D::new(8, 8);
        let any = AnyTopology::from(m);
        assert_eq!(any.kind(), TopologyKind::Mesh);
        assert_eq!(any.dims(), (8, 8));
        for (a, b) in [(0, 63), (9, 9), (17, 40), (63, 0)] {
            assert_eq!(any.hops(a, b), m.hops(a, b));
            assert_eq!(any.route(a, b), m.xy_route(a, b));
            assert_eq!(any.straight_run(a, b), m.straight_run(a, b));
        }
    }
}
