//! The interconnect abstraction (DESIGN.md §1): every NoC model — the
//! flit-level engine ([`super::Network`], wormhole or SMART depending on
//! `hpc_max`, over any [`super::Topology`]) and the analytic
//! [`super::IdealNet`] — implements [`NocBackend`], so drivers (synthetic
//! sweeps, CNN flow co-simulation, the coordinator's ingress model) are
//! written once against the trait and work with any backend, including
//! future ones (buses, analytic queueing models).
//!
//! The trait replaces the seed's closed `NocModel` enum: adding a backend
//! no longer means editing every driver match.

use crate::config::NocKind;
use crate::obs::trace::SharedSink;

use super::ideal::IdealNet;
use super::network::Network;
use super::packet::PacketTable;
use super::topology::AnyTopology;

/// A cycle-addressable interconnect with packet bookkeeping.
///
/// All implementations are event-driven where it matters: [`drain`] skips
/// provably-idle cycle spans, and [`next_event`] exposes the wakeup
/// calendar so callers can schedule around the network.
///
/// # Example
///
/// Drive any backend through the trait — enqueue, drain, read stats:
///
/// ```
/// use smart_pim::config::NocKind;
/// use smart_pim::noc::{build_backend, Mesh, NocBackend};
///
/// let mut net = build_backend(NocKind::Smart, Mesh::new(4, 4), 8, 1, 4);
/// let id = net.enqueue(0, 15, 4); // 4-flit packet, corner to corner
/// net.drain(10_000);
/// assert!(net.quiescent());
/// assert_eq!(net.table().get(id).dst, 15);
/// assert_eq!(net.flits_ejected(), 4);
/// ```
///
/// [`drain`]: NocBackend::drain
/// [`next_event`]: NocBackend::next_event
pub trait NocBackend {
    /// Queue a packet of `len` flits for injection at `src`; returns its id.
    fn enqueue(&mut self, src: usize, dst: usize, len: u16) -> u32;

    /// Advance exactly one cycle.
    fn step(&mut self);

    /// Current cycle.
    fn now(&self) -> u64;

    /// Per-packet bookkeeping (latencies, stop lists, delivery state).
    fn table(&self) -> &PacketTable;

    /// Total flits that entered the fabric.
    fn flits_injected(&self) -> u64;

    /// Total flits ejected at their destination.
    fn flits_ejected(&self) -> u64;

    /// True when every queued packet has been fully delivered.
    fn quiescent(&self) -> bool;

    /// Earliest future cycle at which the network can change state
    /// (`Some(now)` = work pending this cycle; `None` = quiescent).
    fn next_event(&mut self) -> Option<u64>;

    /// Run until quiescent or `max_cycles` elapse; returns cycles run.
    /// Implementations jump over idle spans rather than stepping them.
    fn drain(&mut self, max_cycles: u64) -> u64;

    /// Attach an observability sink for packet-level trace events
    /// (subsystem `"noc"`). Observational only — attaching a sink must
    /// never change routing or stats. Default: events are dropped.
    fn attach_trace(&mut self, _sink: SharedSink) {}
}

impl NocBackend for Network {
    fn enqueue(&mut self, src: usize, dst: usize, len: u16) -> u32 {
        Network::enqueue(self, src, dst, len)
    }

    fn step(&mut self) {
        Network::step(self);
    }

    fn now(&self) -> u64 {
        self.now
    }

    fn table(&self) -> &PacketTable {
        &self.table
    }

    fn flits_injected(&self) -> u64 {
        self.flits_injected
    }

    fn flits_ejected(&self) -> u64 {
        self.flits_ejected
    }

    fn quiescent(&self) -> bool {
        Network::quiescent(self)
    }

    fn next_event(&mut self) -> Option<u64> {
        Network::next_event(self)
    }

    fn drain(&mut self, max_cycles: u64) -> u64 {
        Network::drain(self, max_cycles)
    }

    fn attach_trace(&mut self, sink: SharedSink) {
        Network::attach_trace(self, sink);
    }
}

impl NocBackend for IdealNet {
    fn enqueue(&mut self, src: usize, dst: usize, len: u16) -> u32 {
        IdealNet::enqueue(self, src, dst, len)
    }

    fn step(&mut self) {
        IdealNet::step(self);
    }

    fn now(&self) -> u64 {
        self.now
    }

    fn table(&self) -> &PacketTable {
        &self.table
    }

    fn flits_injected(&self) -> u64 {
        self.flits_injected
    }

    fn flits_ejected(&self) -> u64 {
        self.flits_ejected
    }

    fn quiescent(&self) -> bool {
        IdealNet::quiescent(self)
    }

    fn next_event(&mut self) -> Option<u64> {
        IdealNet::next_event(self)
    }

    fn drain(&mut self, max_cycles: u64) -> u64 {
        IdealNet::drain(self, max_cycles)
    }

    fn attach_trace(&mut self, sink: SharedSink) {
        IdealNet::attach_trace(self, sink);
    }
}

/// Build a backend for a [`NocKind`]. Wormhole is the flit engine with
/// `HPC_max = 1`; SMART is the same engine with the configured reach. The
/// topology (mesh, torus, Parallel-Prism) is orthogonal to the flow
/// control and any `impl Into<AnyTopology>` is accepted.
pub fn build_backend(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    hpc_max: usize,
    router_latency: u64,
    buffer_depth: usize,
) -> Box<dyn NocBackend> {
    let topo = topo.into();
    match kind {
        NocKind::Wormhole => Box::new(Network::new(topo, 1, router_latency, buffer_depth)),
        NocKind::Smart => Box::new(Network::new(topo, hpc_max, router_latency, buffer_depth)),
        NocKind::Ideal => Box::new(IdealNet::new(topo.nodes())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::Mesh;

    fn deliver_all(net: &mut dyn NocBackend) {
        net.enqueue(0, 5, 3);
        net.enqueue(7, 2, 2);
        net.step();
        net.enqueue(3, 12, 4);
        let ran = net.drain(100_000);
        assert!(net.quiescent(), "not quiescent after {ran} cycles");
        assert_eq!(net.flits_injected(), net.flits_ejected());
        for id in 0..net.table().len() as u32 {
            assert!(net.table().get(id).is_done(), "packet {id}");
        }
    }

    #[test]
    fn all_kinds_deliver_through_the_trait() {
        let mesh = Mesh::new(4, 4);
        for kind in NocKind::ALL {
            let mut net = build_backend(kind, mesh, 6, 1, 4);
            deliver_all(net.as_mut());
        }
    }

    #[test]
    fn all_topologies_deliver_through_the_trait() {
        use crate::config::TopologyKind;
        for tk in TopologyKind::ALL {
            let topo = AnyTopology::new(tk, 4, 4);
            for kind in NocKind::ALL {
                let mut net = build_backend(kind, topo, 6, 1, 4);
                deliver_all(net.as_mut());
            }
        }
    }

    #[test]
    fn wormhole_is_mesh_with_hpc_one() {
        // Through the trait, wormhole and SMART must differ only via the
        // bypass: single-packet latency strictly improves under SMART.
        let mesh = Mesh::new(8, 8);
        let lat = |kind| {
            let mut net = build_backend(kind, mesh, 14, 1, 4);
            let id = net.enqueue(0, 63, 4);
            net.drain(100_000);
            net.table().get(id).net_latency()
        };
        assert!(lat(NocKind::Smart) < lat(NocKind::Wormhole));
        assert!(lat(NocKind::Ideal) < lat(NocKind::Smart));
    }

    #[test]
    fn next_event_reports_pending_work() {
        let mesh = Mesh::new(4, 4);
        for kind in NocKind::ALL {
            let mut net = build_backend(kind, mesh, 6, 1, 4);
            assert!(net.next_event().is_none(), "{kind:?} idle at start");
            net.enqueue(0, 3, 2);
            assert!(net.next_event().is_some(), "{kind:?} has work");
            net.drain(100_000);
            assert!(net.next_event().is_none(), "{kind:?} drained");
        }
    }
}
