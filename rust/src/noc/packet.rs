//! Packets and flits.
//!
//! The link width is 128 bits == one flit (Sec. V); a packet is `len` flits
//! (head .. tail). Flits are lightweight ids into a packet table; the
//! per-packet SMART stop list (the sequence of routers where the head
//! actually buffered) lives in the table so body flits replay the head's
//! segmentation exactly — this is what preserves wormhole flit order under
//! multi-hop bypass.

/// A flit in a buffer. `seg` indexes the packet's stop list: the flit
/// currently sits at `stops[seg]` (head flits extend the list as they move).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flit {
    /// Owning packet id.
    pub pkt: u32,
    /// 0 = head; `len-1` = tail.
    pub idx: u16,
    /// Index into the packet's stop list of this flit's current router.
    pub seg: u16,
    /// Cycle at which this flit has finished the router pipeline and may
    /// compete for switch allocation.
    pub ready_at: u64,
}

impl Flit {
    /// Is this the packet's head flit (carries routing state)?
    pub fn is_head(&self) -> bool {
        self.idx == 0
    }
}

/// Book-keeping for one packet.
#[derive(Debug, Clone)]
pub struct PacketState {
    /// Source node id.
    pub src: u32,
    /// Destination node id.
    pub dst: u32,
    /// Packet length in flits.
    pub len: u16,
    /// Cycle the traffic generator created the packet (queueing included).
    pub gen_cycle: u64,
    /// Cycle the head flit entered the network (u64::MAX until then).
    pub inject_cycle: u64,
    /// Flits ejected at dst so far.
    pub delivered: u16,
    /// Cycle the tail flit ejected (u64::MAX until done).
    pub done_cycle: u64,
    /// Routers where the head flit stopped (SMART segmentation), in order.
    /// stops[0] == src. Body flits move stop-to-stop along this list.
    pub stops: Vec<u32>,
}

impl PacketState {
    /// A packet generated at `gen_cycle`.
    pub fn new(src: u32, dst: u32, len: u16, gen_cycle: u64) -> Self {
        Self {
            src,
            dst,
            len,
            gen_cycle,
            inject_cycle: u64::MAX,
            delivered: 0,
            done_cycle: u64::MAX,
            stops: vec![src],
        }
    }

    /// Have all flits been ejected at the destination?
    pub fn is_done(&self) -> bool {
        self.done_cycle != u64::MAX
    }

    /// Network latency: injection of head -> ejection of tail.
    pub fn net_latency(&self) -> u64 {
        debug_assert!(self.is_done());
        self.done_cycle - self.inject_cycle
    }

    /// Total latency including source queueing.
    pub fn total_latency(&self) -> u64 {
        debug_assert!(self.is_done());
        self.done_cycle - self.gen_cycle
    }
}

/// Growable table of packets, indexed by packet id.
#[derive(Debug, Default)]
pub struct PacketTable {
    /// Every packet, indexed by id.
    pub packets: Vec<PacketState>,
}

impl PacketTable {
    /// Register a new packet; returns its id.
    pub fn add(&mut self, src: u32, dst: u32, len: u16, now: u64) -> u32 {
        let id = self.packets.len() as u32;
        self.packets.push(PacketState::new(src, dst, len, now));
        id
    }

    /// Packet by id.
    pub fn get(&self, id: u32) -> &PacketState {
        &self.packets[id as usize]
    }

    /// Mutable packet by id.
    pub fn get_mut(&mut self, id: u32) -> &mut PacketState {
        &mut self.packets[id as usize]
    }

    /// Number of packets registered.
    pub fn len(&self) -> usize {
        self.packets.len()
    }

    /// True when no packet was ever registered.
    pub fn is_empty(&self) -> bool {
        self.packets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_lifecycle() {
        let mut t = PacketTable::default();
        let id = t.add(3, 9, 4, 100);
        assert_eq!(id, 0);
        assert!(!t.get(id).is_done());
        let p = t.get_mut(id);
        p.inject_cycle = 105;
        p.done_cycle = 130;
        p.delivered = 4;
        assert_eq!(t.get(id).net_latency(), 25);
        assert_eq!(t.get(id).total_latency(), 30);
    }

    #[test]
    fn stops_start_at_src() {
        let t = {
            let mut t = PacketTable::default();
            t.add(7, 1, 2, 0);
            t
        };
        assert_eq!(t.get(0).stops, vec![7]);
    }

    #[test]
    fn head_flit_flag() {
        let f = Flit {
            pkt: 0,
            idx: 0,
            seg: 0,
            ready_at: 0,
        };
        assert!(f.is_head());
        let b = Flit { idx: 3, ..f };
        assert!(!b.is_head());
    }
}
