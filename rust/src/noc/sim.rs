//! NoC simulation drivers: synthetic-traffic sweeps (Sec. VII, Figs. 10-11)
//! and flow-based runs for mapped CNNs (Sec. VI).
//!
//! Drivers are written against the [`NocBackend`] trait (DESIGN.md §1), so
//! one loop serves every interconnect. [`StepMode`] selects between the
//! event-driven engine (default) and the seed cycle-stepped engine, which
//! is kept solely as the golden reference: both must report bit-identical
//! [`NocStats`] (`rust/tests/golden_noc_parity.rs`).

use crate::config::NocKind;
use crate::obs::trace::SharedSink;
use crate::util::stats::Accumulator;
use crate::util::Rng;

use super::backend::{build_backend, NocBackend};
use super::network::Network;
use super::packet::PacketTable;
use super::topology::AnyTopology;
use super::traffic::{Flow, FlowPacer, Pattern};

/// Which stepping engine drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Event-driven scheduler (calendar of router wakeups); the default.
    EventDriven,
    /// The seed engine: touch every router every cycle. Golden reference
    /// for parity tests and `--mode reference` CLI runs.
    CycleStepped,
}

impl std::str::FromStr for StepMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "event" | "event-driven" => Ok(StepMode::EventDriven),
            "reference" | "cycle" | "cycle-stepped" => Ok(StepMode::CycleStepped),
            other => Err(format!("unknown step mode {other:?} (event|reference)")),
        }
    }
}

/// Internal driver handle: either any backend through the trait (event
/// path) or the flit engine pinned to its reference stepping functions.
/// The ideal NoC has a single engine, so the reference mode only differs
/// for the routed kinds.
enum DriverNet {
    Backend(Box<dyn NocBackend>),
    Reference(Network),
}

impl DriverNet {
    fn build(
        kind: NocKind,
        topo: AnyTopology,
        hpc_max: usize,
        router_latency: u64,
        buffer_depth: usize,
        mode: StepMode,
    ) -> Self {
        match (mode, kind) {
            (StepMode::CycleStepped, NocKind::Wormhole) => {
                DriverNet::Reference(Network::new(topo, 1, router_latency, buffer_depth))
            }
            (StepMode::CycleStepped, NocKind::Smart) => {
                DriverNet::Reference(Network::new(topo, hpc_max, router_latency, buffer_depth))
            }
            _ => DriverNet::Backend(build_backend(
                kind,
                topo,
                hpc_max,
                router_latency,
                buffer_depth,
            )),
        }
    }

    fn enqueue(&mut self, src: usize, dst: usize, len: u16) -> u32 {
        match self {
            DriverNet::Backend(n) => n.enqueue(src, dst, len),
            DriverNet::Reference(n) => n.enqueue(src, dst, len),
        }
    }

    fn step(&mut self) {
        match self {
            DriverNet::Backend(n) => n.step(),
            DriverNet::Reference(n) => n.step_reference(),
        }
    }

    fn drain(&mut self, max_cycles: u64) -> u64 {
        match self {
            DriverNet::Backend(n) => n.drain(max_cycles),
            DriverNet::Reference(n) => n.drain_reference(max_cycles),
        }
    }

    fn table(&self) -> &PacketTable {
        match self {
            DriverNet::Backend(n) => n.table(),
            DriverNet::Reference(n) => &n.table,
        }
    }

    fn flits_ejected(&self) -> u64 {
        match self {
            DriverNet::Backend(n) => n.flits_ejected(),
            DriverNet::Reference(n) => n.flits_ejected,
        }
    }

    fn attach_trace(&mut self, sink: SharedSink) {
        match self {
            DriverNet::Backend(n) => n.attach_trace(sink),
            DriverNet::Reference(n) => n.attach_trace(sink),
        }
    }
}

/// Configuration of one synthetic-traffic run (one point of Figs. 10-11).
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    /// Synthetic traffic pattern.
    pub pattern: Pattern,
    /// Offered load in flits / node / cycle.
    pub injection_rate: f64,
    /// Flits per packet.
    pub packet_len: u16,
    /// Warmup cycles excluded from stats.
    pub warmup: u64,
    /// Measurement-window cycles.
    pub measure: u64,
    /// Post-measurement drain budget (latency is reported only over packets
    /// generated inside the measurement window that completed).
    pub drain: u64,
    /// Deterministic RNG seed for source processes.
    pub seed: u64,
    /// Wormhole baseline router: (pipeline cycles, buffer depth). The
    /// garnet2.0 default is a multi-stage router; a flit occupies its
    /// buffer slot for the whole pipeline, so with shallow buffers the
    /// per-link service rate is ~ depth / (latency + 2). This is what makes
    /// the paper's wormhole saturate around 0.05 (Figs. 10-11).
    pub wormhole_router: (u64, usize),
    /// SMART router: single-cycle (the premise of SMART [7] is a
    /// bypass-capable 1-cycle router) with standard 4-flit buffers; bypass
    /// then skips even that at intermediate hops.
    pub smart_router: (u64, usize),
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        Self {
            pattern: Pattern::UniformRandom,
            injection_rate: 0.1,
            packet_len: 4,
            warmup: 2_000,
            measure: 10_000,
            drain: 20_000,
            seed: 0xA5A5,
            wormhole_router: (4, 1),
            smart_router: (1, 4),
        }
    }
}

impl SyntheticConfig {
    /// Router (pipeline, buffer depth) for the given flow control.
    pub fn router_for(&self, kind: NocKind) -> (u64, usize) {
        match kind {
            NocKind::Smart => self.smart_router,
            _ => self.wormhole_router,
        }
    }
}

/// Results of one run.
#[derive(Debug, Clone, PartialEq)]
pub struct NocStats {
    /// Offered load (flits/node/cycle).
    pub offered: f64,
    /// Average packet *network* latency (injection -> tail ejection).
    pub avg_net_latency: f64,
    /// Average *total* latency including source queueing.
    pub avg_latency: f64,
    /// Reception rate during the measurement window (flits/node/cycle) —
    /// the y-axis of Fig. 11.
    pub reception_rate: f64,
    /// Packets generated in the window that completed.
    pub completed: u64,
    /// Packets generated in the window that never completed (saturation).
    pub dropped: u64,
}

impl NocStats {
    /// Heuristic saturation flag: unbounded queueing shows up as total
    /// latency far above network latency or unfinished packets.
    pub fn saturated(&self) -> bool {
        self.dropped > self.completed / 10
            || self.avg_latency > 8.0 * self.avg_net_latency.max(1.0)
    }
}

/// Run one synthetic-traffic point (Figs. 10-11 are sweeps of this) with
/// the event-driven engine.
pub fn run_synthetic(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    cfg: &SyntheticConfig,
    hpc_max: usize,
) -> NocStats {
    run_synthetic_with(kind, topo, cfg, hpc_max, StepMode::EventDriven)
}

/// Run one synthetic-traffic point with an explicit stepping engine. The
/// traffic generator draws the RNG identically in both modes, so the two
/// engines are fed bit-identical packet streams and must report
/// bit-identical stats.
pub fn run_synthetic_with(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    cfg: &SyntheticConfig,
    hpc_max: usize,
    mode: StepMode,
) -> NocStats {
    run_synthetic_traced(kind, topo, cfg, hpc_max, mode, None)
}

/// [`run_synthetic_with`] with an optional trace sink attached to the
/// backend (packet inject/hop/bypass/eject events, subsystem `"noc"`).
/// Tracing is observational: stats are bit-identical with or without a
/// sink (`tests/obs_parity.rs`).
pub fn run_synthetic_traced(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    cfg: &SyntheticConfig,
    hpc_max: usize,
    mode: StepMode,
    trace: Option<SharedSink>,
) -> NocStats {
    let _prof = crate::obs::profile::scope("noc.synthetic_point");
    let topo = topo.into();
    let (rl, depth) = cfg.router_for(kind);
    let mut net = DriverNet::build(kind, topo, hpc_max, rl, depth, mode);
    if let Some(sink) = trace {
        net.attach_trace(sink);
    }
    let mut rng = Rng::new(cfg.seed);
    // Bernoulli packet generation: rate flits/node/cycle -> p per cycle.
    let p_gen = cfg.injection_rate / cfg.packet_len as f64;
    let mut window_pkts: Vec<u32> = Vec::new();
    let mut ejected_at_warmup = 0u64;
    let mut ejected_at_end = 0u64;

    let total = cfg.warmup + cfg.measure;
    for cycle in 0..total {
        if cycle == cfg.warmup {
            ejected_at_warmup = net.flits_ejected();
        }
        for src in 0..topo.nodes() {
            if rng.chance(p_gen) {
                if let Some(dst) = cfg.pattern.dest_on(&topo, src, &mut rng) {
                    let id = net.enqueue(src, dst, cfg.packet_len);
                    if cycle >= cfg.warmup {
                        window_pkts.push(id);
                    }
                }
            }
        }
        net.step();
        if cycle + 1 == total {
            ejected_at_end = net.flits_ejected();
        }
    }
    // Drain (no new traffic) so window packets can finish.
    net.drain(cfg.drain);

    let mut net_lat = Accumulator::new();
    let mut tot_lat = Accumulator::new();
    let mut dropped = 0u64;
    for &id in &window_pkts {
        let p = net.table().get(id);
        if p.is_done() {
            net_lat.add(p.net_latency() as f64);
            tot_lat.add(p.total_latency() as f64);
        } else {
            dropped += 1;
        }
    }
    NocStats {
        offered: cfg.injection_rate,
        avg_net_latency: net_lat.mean(),
        avg_latency: tot_lat.mean(),
        reception_rate: (ejected_at_end - ejected_at_warmup) as f64
            / (topo.nodes() as f64 * cfg.measure as f64),
        completed: net_lat.count(),
        dropped,
    }
}

/// Per-flow outcome of [`run_flows_detailed`].
#[derive(Debug, Clone)]
pub struct FlowStats {
    /// Mean network latency of completed window packets (cycles).
    pub avg_net_latency: f64,
    /// Mean total latency (incl. source queueing).
    pub avg_latency: f64,
    /// Window packets completed / offered — an accepted-rate proxy; < 1
    /// means the mesh cannot sustain this flow's offered load.
    pub completion_ratio: f64,
    /// Packets offered during the measurement window.
    pub offered_window: u64,
    /// Packets completed during the measurement window.
    pub completed_window: u64,
    /// Packets fully delivered over the whole run.
    pub completed: u64,
    /// Packets still undelivered when the drain budget expired.
    pub dropped: u64,
}

/// Like [`run_flows`] but reports per-flow statistics (the CNN coupling
/// needs per-layer latency and acceptance).
#[allow(clippy::too_many_arguments)]
pub fn run_flows_detailed(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    flows: &[Flow],
    warmup: u64,
    measure: u64,
    drain: u64,
    hpc_max: usize,
    router_latency: u64,
    buffer_depth: usize,
) -> Vec<FlowStats> {
    run_flows_detailed_traced(
        kind,
        topo,
        flows,
        warmup,
        measure,
        drain,
        hpc_max,
        router_latency,
        buffer_depth,
        None,
    )
}

/// [`run_flows_detailed`] with an optional trace sink attached to the
/// backend. Observational only; per-flow stats are bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn run_flows_detailed_traced(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    flows: &[Flow],
    warmup: u64,
    measure: u64,
    drain: u64,
    hpc_max: usize,
    router_latency: u64,
    buffer_depth: usize,
    trace: Option<SharedSink>,
) -> Vec<FlowStats> {
    let mut net = build_backend(kind, topo, hpc_max, router_latency, buffer_depth);
    if let Some(sink) = trace {
        net.attach_trace(sink);
    }
    let mut pacers: Vec<FlowPacer> = flows.iter().map(|&f| FlowPacer::new(f)).collect();
    // All packets ever generated per flow, plus how many were offered
    // inside the measurement window.
    let mut all_pkts: Vec<Vec<u32>> = vec![Vec::new(); flows.len()];
    let mut offered_window = vec![0u64; flows.len()];
    let total = warmup + measure;
    for cycle in 0..total {
        for (fi, pacer) in pacers.iter_mut().enumerate() {
            for _ in 0..pacer.tick() {
                let f = pacer.flow;
                let id = net.enqueue(f.src, f.dst, f.packet_len);
                all_pkts[fi].push(id);
                if cycle >= warmup {
                    offered_window[fi] += 1;
                }
            }
        }
        net.step();
    }
    net.drain(drain);
    all_pkts
        .iter()
        .enumerate()
        .map(|(fi, pkts)| {
            let mut net_lat = Accumulator::new();
            let mut tot_lat = Accumulator::new();
            let mut dropped = 0u64;
            // Steady-state throughput proxy: packets *completed during* the
            // window over packets *offered during* the window. (Counting
            // only window-generated packets to completion would conflate
            // queue backlog with loss.)
            let mut completed_window = 0u64;
            for &id in pkts {
                let p = net.table().get(id);
                if p.is_done() {
                    if p.done_cycle >= warmup && p.done_cycle < total {
                        completed_window += 1;
                    }
                    if p.gen_cycle >= warmup {
                        net_lat.add(p.net_latency() as f64);
                        tot_lat.add(p.total_latency() as f64);
                    }
                } else if p.gen_cycle >= warmup {
                    dropped += 1;
                }
            }
            // A flow too slow to offer window packets shows no evidence of
            // saturation: ratio 1.
            let completion_ratio = if offered_window[fi] == 0 {
                1.0
            } else {
                (completed_window as f64 / offered_window[fi] as f64).min(1.0)
            };
            FlowStats {
                avg_net_latency: net_lat.mean(),
                avg_latency: tot_lat.mean(),
                completion_ratio,
                offered_window: offered_window[fi],
                completed_window,
                completed: net_lat.count(),
                dropped,
            }
        })
        .collect()
}

/// Run a set of deterministic point-to-point flows (mapped-CNN traffic).
/// Returns aggregate stats over the measurement window.
#[allow(clippy::too_many_arguments)]
pub fn run_flows(
    kind: NocKind,
    topo: impl Into<AnyTopology>,
    flows: &[Flow],
    warmup: u64,
    measure: u64,
    drain: u64,
    hpc_max: usize,
    router_latency: u64,
    buffer_depth: usize,
) -> NocStats {
    let topo = topo.into();
    let mut net = build_backend(kind, topo, hpc_max, router_latency, buffer_depth);
    let mut pacers: Vec<FlowPacer> = flows.iter().map(|&f| FlowPacer::new(f)).collect();
    let mut window_pkts: Vec<u32> = Vec::new();
    let mut ejected_at_warmup = 0u64;
    let mut ejected_at_end = 0u64;
    let offered: f64 = flows
        .iter()
        .map(|f| f.packets_per_cycle * f.packet_len as f64)
        .sum::<f64>()
        / topo.nodes() as f64;

    let total = warmup + measure;
    for cycle in 0..total {
        if cycle == warmup {
            ejected_at_warmup = net.flits_ejected();
        }
        for pacer in &mut pacers {
            for _ in 0..pacer.tick() {
                let f = pacer.flow;
                let id = net.enqueue(f.src, f.dst, f.packet_len);
                if cycle >= warmup {
                    window_pkts.push(id);
                }
            }
        }
        net.step();
        if cycle + 1 == total {
            ejected_at_end = net.flits_ejected();
        }
    }
    net.drain(drain);

    let mut net_lat = Accumulator::new();
    let mut tot_lat = Accumulator::new();
    let mut dropped = 0u64;
    for &id in &window_pkts {
        let p = net.table().get(id);
        if p.is_done() {
            net_lat.add(p.net_latency() as f64);
            tot_lat.add(p.total_latency() as f64);
        } else {
            dropped += 1;
        }
    }
    NocStats {
        offered,
        avg_net_latency: net_lat.mean(),
        avg_latency: tot_lat.mean(),
        reception_rate: (ejected_at_end - ejected_at_warmup) as f64
            / (topo.nodes() as f64 * measure as f64),
        completed: net_lat.count(),
        dropped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::Mesh;

    fn quick(kind: NocKind, rate: f64, pattern: Pattern) -> NocStats {
        let cfg = SyntheticConfig {
            pattern,
            injection_rate: rate,
            packet_len: 4,
            warmup: 500,
            measure: 2_000,
            drain: 8_000,
            seed: 7,
            ..Default::default()
        };
        run_synthetic(kind, Mesh::new(8, 8), &cfg, 14)
    }

    #[test]
    fn low_load_everything_completes() {
        for kind in [NocKind::Wormhole, NocKind::Smart, NocKind::Ideal] {
            let s = quick(kind, 0.02, Pattern::UniformRandom);
            assert!(s.completed > 0, "{kind:?}");
            assert_eq!(s.dropped, 0, "{kind:?} dropped {}", s.dropped);
            assert!(!s.saturated(), "{kind:?} saturated at 0.02");
        }
    }

    #[test]
    fn latency_order_ideal_smart_wormhole() {
        // Fig. 10's zero-load ordering: ideal < smart < wormhole.
        let w = quick(NocKind::Wormhole, 0.02, Pattern::UniformRandom);
        let s = quick(NocKind::Smart, 0.02, Pattern::UniformRandom);
        let i = quick(NocKind::Ideal, 0.02, Pattern::UniformRandom);
        assert!(
            i.avg_net_latency < s.avg_net_latency,
            "ideal {} !< smart {}",
            i.avg_net_latency,
            s.avg_net_latency
        );
        assert!(
            s.avg_net_latency < w.avg_net_latency,
            "smart {} !< wormhole {}",
            s.avg_net_latency,
            w.avg_net_latency
        );
    }

    #[test]
    fn wormhole_saturates_before_smart() {
        // Fig. 10: wormhole saturates around 0.05, SMART around 0.25 for
        // uniform random. At 0.15 wormhole must be saturated, SMART not.
        let w = quick(NocKind::Wormhole, 0.15, Pattern::UniformRandom);
        let s = quick(NocKind::Smart, 0.15, Pattern::UniformRandom);
        assert!(
            w.saturated() || w.avg_latency > 4.0 * s.avg_latency,
            "wormhole lat {} vs smart {}",
            w.avg_latency,
            s.avg_latency
        );
        assert!(!s.saturated(), "smart saturated at 0.15: {s:?}");
    }

    #[test]
    fn neighbor_tolerates_high_load() {
        // Fig. 10: neighbor traffic saturates much later (SMART ~0.8).
        let s = quick(NocKind::Smart, 0.5, Pattern::Neighbor);
        assert!(!s.saturated(), "{s:?}");
    }

    #[test]
    fn reception_tracks_offered_below_saturation() {
        let s = quick(NocKind::Smart, 0.1, Pattern::Transpose);
        assert!(
            (s.reception_rate - 0.1 * 7.0 / 8.0).abs() < 0.04,
            "reception {} (transpose diagonal idles 8/64 nodes)",
            s.reception_rate
        );
    }

    #[test]
    fn flow_run_delivers() {
        let flows = vec![
            Flow {
                src: 0,
                dst: 10,
                packets_per_cycle: 0.05,
                packet_len: 4,
            },
            Flow {
                src: 63,
                dst: 3,
                packets_per_cycle: 0.05,
                packet_len: 4,
            },
        ];
        let s = run_flows(
            NocKind::Smart,
            Mesh::new(8, 8),
            &flows,
            200,
            1_000,
            5_000,
            14,
            1,
            4,
        );
        assert!(s.completed > 80, "{s:?}");
        assert_eq!(s.dropped, 0);
    }

    #[test]
    fn step_modes_report_identical_stats() {
        // A quick in-crate parity smoke test; the exhaustive grid lives in
        // rust/tests/golden_noc_parity.rs.
        let cfg = SyntheticConfig {
            pattern: Pattern::Transpose,
            injection_rate: 0.06,
            warmup: 300,
            measure: 1_200,
            drain: 5_000,
            seed: 0x51EE7,
            ..Default::default()
        };
        for kind in [NocKind::Wormhole, NocKind::Smart] {
            let ev = run_synthetic_with(kind, Mesh::new(8, 8), &cfg, 14, StepMode::EventDriven);
            let re = run_synthetic_with(kind, Mesh::new(8, 8), &cfg, 14, StepMode::CycleStepped);
            assert_eq!(ev, re, "{kind:?} engines diverged");
        }
    }

    #[test]
    fn torus_and_prism_run_clean_at_low_load() {
        use crate::config::TopologyKind;
        let cfg = SyntheticConfig {
            injection_rate: 0.02,
            warmup: 300,
            measure: 1_000,
            drain: 6_000,
            seed: 11,
            ..Default::default()
        };
        for tk in [TopologyKind::Torus, TopologyKind::Prism] {
            let topo = AnyTopology::new(tk, 8, 8);
            for kind in [NocKind::Wormhole, NocKind::Smart, NocKind::Ideal] {
                let s = run_synthetic(kind, topo, &cfg, 14);
                assert!(s.completed > 0, "{tk:?} {kind:?}: {s:?}");
                assert_eq!(s.dropped, 0, "{tk:?} {kind:?}: {s:?}");
            }
        }
    }

    #[test]
    fn step_modes_agree_on_every_topology() {
        use crate::config::TopologyKind;
        let cfg = SyntheticConfig {
            pattern: Pattern::UniformRandom,
            injection_rate: 0.05,
            warmup: 200,
            measure: 800,
            drain: 4_000,
            seed: 0xBEEF,
            ..Default::default()
        };
        for tk in TopologyKind::ALL {
            let topo = AnyTopology::new(tk, 8, 8);
            for kind in [NocKind::Wormhole, NocKind::Smart] {
                let ev = run_synthetic_with(kind, topo, &cfg, 14, StepMode::EventDriven);
                let re = run_synthetic_with(kind, topo, &cfg, 14, StepMode::CycleStepped);
                assert_eq!(ev, re, "{tk:?} {kind:?} engines diverged");
            }
        }
    }

    #[test]
    fn step_mode_parses() {
        assert_eq!("event".parse::<StepMode>().unwrap(), StepMode::EventDriven);
        assert_eq!(
            "reference".parse::<StepMode>().unwrap(),
            StepMode::CycleStepped
        );
        assert!("warp".parse::<StepMode>().is_err());
    }
}
