//! Traffic generation: the six synthetic patterns of Sec. VII plus
//! flow-based traffic extracted from a mapped CNN (Sec. VI).

use crate::util::Rng;

use super::topology::{AnyTopology, Mesh};

/// Synthetic traffic patterns (garnet2.0's standard set, Sec. VII-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// Every destination equally likely.
    UniformRandom,
    /// (x, y) sends to (y, x).
    Transpose,
    /// Half-mesh offset along x (adversarial for rings/meshes).
    Tornado,
    /// Bit-rotate the node id.
    Shuffle,
    /// Fixed one-hop neighbor (best case).
    Neighbor,
    /// Send to the bit-complemented node id.
    BitComplement,
}

impl Pattern {
    /// Every pattern, in Figs. 10-11 order.
    pub const ALL: [Pattern; 6] = [
        Pattern::UniformRandom,
        Pattern::Transpose,
        Pattern::Tornado,
        Pattern::Shuffle,
        Pattern::Neighbor,
        Pattern::BitComplement,
    ];

    /// Pattern name as used by `--pattern`.
    pub fn name(&self) -> &'static str {
        match self {
            Pattern::UniformRandom => "uniform_random",
            Pattern::Transpose => "transpose",
            Pattern::Tornado => "tornado",
            Pattern::Shuffle => "shuffle",
            Pattern::Neighbor => "neighbor",
            Pattern::BitComplement => "bit_complement",
        }
    }

    /// Destination for a packet from `src`. `None` if the pattern maps the
    /// node to itself (no traffic from this node).
    pub fn dest(&self, mesh: &Mesh, src: usize, rng: &mut Rng) -> Option<usize> {
        let (x, y) = mesh.xy(src);
        let (w, h) = (mesh.w, mesh.h);
        let dst = match self {
            Pattern::UniformRandom => {
                // Uniform over all nodes except src.
                let d = rng.below_usize(mesh.nodes() - 1);
                if d >= src {
                    d + 1
                } else {
                    d
                }
            }
            Pattern::Transpose => {
                // (x, y) -> (y, x); needs a square mesh to be total.
                let (tx, ty) = (y % w, x % h);
                mesh.id(tx, ty)
            }
            Pattern::Tornado => {
                // Half-way around the X ring.
                let tx = (x + w.div_ceil(2) - 1) % w;
                mesh.id(tx, y)
            }
            Pattern::Shuffle => {
                // Rotate the node-id bits left by one (power-of-two sizes).
                let n = mesh.nodes();
                debug_assert!(n.is_power_of_two());
                let bits = n.trailing_zeros();
                let id = src;
                ((id << 1) | (id >> (bits - 1))) & (n - 1)
            }
            Pattern::Neighbor => mesh.id((x + 1) % w, y),
            Pattern::BitComplement => mesh.id(w - 1 - x, h - 1 - y),
        };
        (dst != src).then_some(dst)
    }

    /// [`Pattern::dest`] over any topology. The pattern's coordinate map is
    /// defined on the logical `(w, h)` grid, which all topologies share
    /// (they differ in *links*, not node layout), so the destination is
    /// computed on the grid and is bit-identical to [`Pattern::dest`] for
    /// the mesh — only routing below changes per topology.
    pub fn dest_on(&self, topo: &AnyTopology, src: usize, rng: &mut Rng) -> Option<usize> {
        let (w, h) = topo.dims();
        self.dest(&Mesh::new(w, h), src, rng)
    }
}

impl std::str::FromStr for Pattern {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Pattern::ALL
            .iter()
            .find(|p| p.name() == s)
            .copied()
            .ok_or_else(|| {
                format!(
                    "unknown pattern {s:?} (one of {:?})",
                    Pattern::ALL.map(|p| p.name())
                )
            })
    }
}

/// A point-to-point flow with a deterministic injection rate, used to model
/// inter-layer OFM traffic of a mapped CNN.
#[derive(Debug, Clone, Copy)]
pub struct Flow {
    /// Source node id.
    pub src: usize,
    /// Destination node id.
    pub dst: usize,
    /// Offered load in packets per cycle (may exceed 1 only via multiple
    /// flows; a single flow saturates at its source's injection port).
    pub packets_per_cycle: f64,
    /// Flits per packet of this flow.
    pub packet_len: u16,
}

/// Deterministic fractional-rate pacing: injects `rate` packets/cycle on
/// average using an error accumulator (no RNG, so flow experiments are
/// exactly reproducible).
#[derive(Debug, Clone)]
pub struct FlowPacer {
    /// The flow being generated.
    pub flow: Flow,
    credit: f64,
}

impl FlowPacer {
    /// A Bernoulli source for one flow.
    pub fn new(flow: Flow) -> Self {
        Self { flow, credit: 0.0 }
    }

    /// Packets to inject this cycle.
    pub fn tick(&mut self) -> usize {
        self.credit += self.flow.packets_per_cycle;
        let n = self.credit.floor() as usize;
        self.credit -= n as f64;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh() -> Mesh {
        Mesh::new(8, 8)
    }

    #[test]
    fn bit_complement_is_involution() {
        let m = mesh();
        let mut rng = Rng::new(1);
        for src in 0..m.nodes() {
            if let Some(d) = Pattern::BitComplement.dest(&m, src, &mut rng) {
                let back = Pattern::BitComplement.dest(&m, d, &mut rng).unwrap();
                assert_eq!(back, src);
            }
        }
    }

    #[test]
    fn transpose_swaps_coordinates() {
        let m = mesh();
        let mut rng = Rng::new(1);
        let src = m.id(2, 5);
        assert_eq!(
            Pattern::Transpose.dest(&m, src, &mut rng),
            Some(m.id(5, 2))
        );
        // Diagonal maps to itself -> no packet.
        assert_eq!(Pattern::Transpose.dest(&m, m.id(3, 3), &mut rng), None);
    }

    #[test]
    fn tornado_is_half_ring() {
        let m = mesh();
        let mut rng = Rng::new(1);
        let src = m.id(0, 2);
        assert_eq!(Pattern::Tornado.dest(&m, src, &mut rng), Some(m.id(3, 2)));
    }

    #[test]
    fn neighbor_wraps() {
        let m = mesh();
        let mut rng = Rng::new(1);
        assert_eq!(
            Pattern::Neighbor.dest(&m, m.id(7, 0), &mut rng),
            Some(m.id(0, 0))
        );
    }

    #[test]
    fn shuffle_rotates_bits() {
        let m = mesh();
        let mut rng = Rng::new(1);
        // 64 nodes = 6 bits; 0b000001 -> 0b000010.
        assert_eq!(Pattern::Shuffle.dest(&m, 1, &mut rng), Some(2));
        // 0b100000 -> 0b000001.
        assert_eq!(Pattern::Shuffle.dest(&m, 32, &mut rng), Some(1));
        assert_eq!(Pattern::Shuffle.dest(&m, 0, &mut rng), None);
    }

    #[test]
    fn uniform_random_never_self() {
        let m = mesh();
        let mut rng = Rng::new(42);
        for _ in 0..5_000 {
            let src = rng.below_usize(m.nodes());
            let d = Pattern::UniformRandom.dest(&m, src, &mut rng).unwrap();
            assert_ne!(d, src);
            assert!(d < m.nodes());
        }
    }

    #[test]
    fn dest_on_matches_mesh_dest() {
        use crate::config::TopologyKind;
        let m = mesh();
        for kind in TopologyKind::ALL {
            let topo = AnyTopology::new(kind, 8, 8);
            for pattern in Pattern::ALL {
                // Same seed -> identical RNG draws -> identical destinations
                // (the coordinate map is topology-independent).
                let mut ra = Rng::new(9);
                let mut rb = Rng::new(9);
                for src in 0..m.nodes() {
                    assert_eq!(
                        pattern.dest_on(&topo, src, &mut ra),
                        pattern.dest(&m, src, &mut rb),
                        "{kind:?} {pattern:?} src {src}"
                    );
                }
            }
        }
    }

    #[test]
    fn parse_names() {
        for p in Pattern::ALL {
            assert_eq!(p.name().parse::<Pattern>().unwrap(), p);
        }
        assert!("diagonal".parse::<Pattern>().is_err());
    }

    #[test]
    fn pacer_hits_rate() {
        let mut p = FlowPacer::new(Flow {
            src: 0,
            dst: 1,
            packets_per_cycle: 0.3,
            packet_len: 4,
        });
        let total: usize = (0..1000).map(|_| p.tick()).sum();
        // floating-point credit accumulation may lose one ulp-packet
        assert!((299..=300).contains(&total), "total {total}");
    }
}
