//! Flit-level cycle-accurate NoC: wormhole flow control with optional
//! SMART single-cycle multi-hop bypass (Sec. V), over any
//! [`super::topology::Topology`] (mesh / torus / prism — the engine asks
//! the [`AnyTopology`] carrier for routes, straight runs, and links and
//! hard-codes no XY math).
//!
//! One engine implements both: `hpc_max = 1` *is* the wormhole baseline
//! (every flit buffers at every router and pays the full router pipeline);
//! `hpc_max > 1` enables SMART: a flit that wins switch allocation traverses
//! up to `hpc_max` hops along its topology straight run in a single cycle,
//! bypassing the intermediate router pipelines, with the paper's SSR
//! priority rule — a *buffered* (local) flit at an intermediate router beats
//! a bypassing flit, truncating the bypass at that router.
//!
//! Wormhole semantics are preserved under bypass: the head flit records the
//! routers where it actually stopped (the packet's *stop list*) and body
//! flits replay exactly that segmentation, so flits of a packet can never
//! reorder. Output ports are locked packet-wise from head to tail, exactly
//! like single-VC wormhole.
//!
//! ## Two stepping engines, one state (DESIGN.md §1)
//!
//! [`Network::step`] is *event-driven*: a binary-heap calendar of router
//! wakeups means only routers that can possibly act this cycle are touched,
//! and idle routers cost nothing. [`Network::step_reference`] is the seed
//! cycle-stepped engine (full snapshot of every router every cycle), kept
//! as the golden reference: `rust/tests/golden_noc_parity.rs` proves the
//! two produce bit-identical [`super::sim::NocStats`]. A given `Network`
//! instance must be driven exclusively through one of the two engines —
//! the reference path does not maintain the wakeup calendar.
//!
//! The event-driven argument, in brief: a router is *routable* at cycle `t`
//! only if some input port's head flit has `ready_at <= t`. Every state
//! change that can create that condition (a flit landing, a head advancing
//! in its buffer, a ready head losing arbitration or being blocked) pushes
//! a wakeup, so a router with no pending wakeup is provably inert and the
//! cycle-stepped scan over it is a no-op that can be skipped wholesale.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::obs::trace::{SharedSink, TraceEvent, TracePhase};

use super::packet::{Flit, PacketTable};
use super::topology::{AnyTopology, Dir};

const PORTS: usize = 5;

/// Cycle-accurate NoC (wormhole / SMART) over any shipped topology.
pub struct Network {
    /// Fabric geometry/routing this router array covers.
    pub topo: AnyTopology,
    /// Max hops traversed per cycle: 1 = wormhole, >1 = SMART HPC_max.
    pub hpc_max: usize,
    /// Router pipeline depth in cycles (buffer write .. switch allocation).
    pub router_latency: u64,
    /// Input buffer depth in flits.
    pub buffer_depth: usize,
    /// Input buffers: `node * 5 + dir`.
    buffers: Vec<VecDeque<Flit>>,
    /// Packet-wise output locks: `node * 5 + dir`.
    out_lock: Vec<Option<u32>>,
    /// Round-robin arbitration pointer per output port.
    rr: Vec<usize>,
    /// Cycle stamp of the last use of each directed link (`== now` means
    /// used this cycle; replaces the seed engine's per-cycle clear of a
    /// bool vector, which cost O(links) even on idle cycles).
    link_stamp: Vec<u64>,
    /// Cycle stamp of the last use of each ejection port.
    eject_stamp: Vec<u64>,
    /// Per-node source queues of packet ids awaiting injection.
    src_q: Vec<VecDeque<u32>>,
    /// Next flit index to inject for the packet at the front of each queue.
    src_next_flit: Vec<u16>,
    /// Cycle-start snapshot: desired output of each ready head flit
    /// (`Dir::index()`, or `NO_DESIRE`). An entry is invalidated when its
    /// flit moves so a port routes at most once per cycle. This is both the
    /// hot-path cache and the faithful model of SMART's SSRs, which are
    /// broadcast a cycle ahead of traversal.
    desired: Vec<u8>,
    /// Contender mask per node: bit `d` set iff some ready buffered flit
    /// wants output `d` (the SSR priority input). Maintained so that it
    /// always equals what the cycle-stepped engine would have computed at
    /// the current cycle start (see `reschedule_node`).
    contenders: Vec<u8>,
    /// Flits currently buffered (incremental, for O(1) quiescence).
    buffered: usize,
    /// Buffered flits per node (lets the snapshot skip idle routers).
    node_flits: Vec<u16>,
    /// Packets still (partially) waiting in source queues.
    src_pkts: usize,
    /// Event calendar: (cycle, node) router wakeups, min-first.
    wake: BinaryHeap<Reverse<(u64, u32)>>,
    /// Earliest pending wakeup per node (`u64::MAX` = none); dedups heap
    /// entries and lets stale ones be discarded on pop.
    wake_at: Vec<u64>,
    /// Scratch list of routers woken this cycle (kept sorted ascending so
    /// switch allocation visits nodes in exactly the seed engine's order).
    woken: Vec<u32>,
    /// Nodes with a non-empty source queue (event-driven injection scan).
    active_src: Vec<u32>,
    src_active: Vec<bool>,
    /// Optional trace sink (None = zero overhead beyond one `Option`
    /// check per packet event site; behavior is identical either way).
    trace: Option<SharedSink>,
    /// All packets ever injected (stats source).
    pub table: PacketTable,
    /// Current NoC cycle.
    pub now: u64,
    /// Total flits accepted into source queues.
    pub flits_injected: u64,
    /// Total flits ejected at their destination.
    pub flits_ejected: u64,
}

const NO_DESIRE: u8 = u8::MAX;
/// Stack bound for one planned segment: body flits replay head segments,
/// each of which is <= max(HPC_max, straight mesh run). 64 covers meshes up
/// to 64 nodes per dimension.
const MAX_SEG: usize = 64;

impl Network {
    /// A network over `topo` (any [`AnyTopology`]-convertible fabric);
    /// `hpc_max = 1` is the wormhole baseline, `hpc_max > 1` enables SMART
    /// multi-hop bypass.
    pub fn new(
        topo: impl Into<AnyTopology>,
        hpc_max: usize,
        router_latency: u64,
        buffer_depth: usize,
    ) -> Self {
        let topo = topo.into();
        assert!(hpc_max >= 1);
        assert!(buffer_depth >= 1);
        let n = topo.nodes();
        Self {
            topo,
            hpc_max,
            router_latency,
            buffer_depth,
            buffers: vec![VecDeque::new(); n * PORTS],
            out_lock: vec![None; n * PORTS],
            rr: vec![0; n * PORTS],
            link_stamp: vec![u64::MAX; topo.n_links()],
            eject_stamp: vec![u64::MAX; n],
            src_q: vec![VecDeque::new(); n],
            src_next_flit: vec![0; n],
            desired: vec![NO_DESIRE; n * PORTS],
            contenders: vec![0; n],
            buffered: 0,
            node_flits: vec![0; n],
            src_pkts: 0,
            wake: BinaryHeap::new(),
            wake_at: vec![u64::MAX; n],
            woken: Vec::new(),
            active_src: Vec::new(),
            src_active: vec![false; n],
            trace: None,
            table: PacketTable::default(),
            now: 0,
            flits_injected: 0,
            flits_ejected: 0,
        }
    }

    /// Report timeline events (packet inject/hop/bypass/eject, subsystem
    /// `"noc"`, track = node) to `sink`. Tracing is observational only:
    /// routing, arbitration, and every stat stay bit-identical
    /// (`tests/obs_parity.rs`).
    pub fn attach_trace(&mut self, sink: SharedSink) {
        self.trace = Some(sink);
    }

    /// True when a sink is attached and currently recording.
    #[inline]
    fn tracing(&self) -> bool {
        self.trace.as_ref().is_some_and(|t| t.borrow().enabled())
    }

    /// Record one instant event at (`node`, `name`) — call only after a
    /// [`Self::tracing`] check.
    fn trace_instant(&self, node: usize, name: &'static str, args: Vec<(&'static str, u64)>) {
        if let Some(t) = &self.trace {
            t.borrow_mut().record(TraceEvent {
                subsystem: "noc",
                track: node as u64,
                name,
                ts: self.now,
                phase: TracePhase::Instant,
                args,
            });
        }
    }

    /// Queue a packet for injection at `src`. Returns the packet id.
    pub fn enqueue(&mut self, src: usize, dst: usize, len: u16) -> u32 {
        debug_assert!(src < self.topo.nodes() && dst < self.topo.nodes());
        debug_assert!(src != dst, "self-addressed packet");
        debug_assert!(len >= 1);
        let id = self.table.add(src as u32, dst as u32, len, self.now);
        self.src_q[src].push_back(id);
        self.src_pkts += 1;
        if !self.src_active[src] {
            self.src_active[src] = true;
            self.active_src.push(src as u32);
        }
        id
    }

    /// All queues and buffers empty (every injected packet delivered).
    pub fn quiescent(&self) -> bool {
        self.src_pkts == 0 && self.buffered == 0
    }

    /// Flits currently buffered in the network.
    pub fn in_flight_flits(&self) -> usize {
        self.buffered
    }

    fn buf(&self, node: usize, port: Dir) -> &VecDeque<Flit> {
        &self.buffers[node * PORTS + port.index()]
    }

    /// Desired output direction at `node` for buffered flit `f`.
    fn desired_out(&self, node: usize, f: &Flit) -> Dir {
        let p = self.table.get(f.pkt);
        if node as u32 == p.dst {
            return Dir::Local;
        }
        if f.is_head() {
            self.topo.route(node, p.dst as usize)
        } else {
            // Body flits replay the head's stop list.
            let next = p.stops[f.seg as usize + 1] as usize;
            self.topo.route(node, next)
        }
    }

    /// Is there a ready buffered flit at `node` that wants output `d`?
    /// (The SSR priority rule: local flits beat bypassing flits.) Reads the
    /// cycle-start SSR snapshot.
    #[inline]
    fn has_local_contender(&self, node: usize, d: Dir) -> bool {
        self.contenders[node] & (1 << d.index()) != 0
    }

    /// Schedule a router wakeup at cycle `t` (deduplicated: only pushed if
    /// earlier than the node's current earliest pending wakeup).
    #[inline]
    fn schedule_wake(&mut self, node: usize, t: u64) {
        if t < self.wake_at[node] {
            self.wake_at[node] = t;
            self.wake.push(Reverse((t, node as u32)));
        }
    }

    /// Earliest pending (non-stale) wakeup, pruning stale heap entries.
    fn next_wake(&mut self) -> Option<u64> {
        while let Some(&Reverse((t, node))) = self.wake.peek() {
            if self.wake_at[node as usize] == t {
                return Some(t);
            }
            self.wake.pop();
        }
        None
    }

    /// Refresh the per-cycle SSR snapshot (desired outputs + contender
    /// masks) for every node — the seed engine's full scan. Incremental: a
    /// head flit's desire is a pure function of (node, flit), so an entry
    /// stays valid until that flit moves (moves reset it to NO_DESIRE);
    /// only invalidated or newly-ready ports are recomputed.
    fn snapshot_desires(&mut self) {
        for node in 0..self.topo.nodes() {
            if self.node_flits[node] == 0 {
                self.contenders[node] = 0;
                continue;
            }
            self.refresh_node(node);
        }
    }

    /// Per-node SSR snapshot refresh (shared by both engines): set desires
    /// for ready head flits and recompute the node's contender mask.
    fn refresh_node(&mut self, node: usize) {
        if self.node_flits[node] == 0 {
            self.contenders[node] = 0;
            return;
        }
        let mut mask = 0u8;
        for port in 0..PORTS {
            let idx = node * PORTS + port;
            let mut d = self.desired[idx];
            if d == NO_DESIRE {
                if let Some(f) = self.buffers[idx].front() {
                    if f.ready_at <= self.now {
                        d = self.desired_out(node, f).index() as u8;
                        self.desired[idx] = d;
                    }
                }
            }
            if d != NO_DESIRE {
                mask |= 1 << d;
            }
        }
        self.contenders[node] = mask;
    }

    /// Post-routing bookkeeping for a woken node: bring its contender mask
    /// back in line with the cycle-stepped engine's next snapshot (set
    /// desires are always ready flits, so the mask is their OR) and push
    /// the node's next wakeup — `now + 1` if any head is already ready,
    /// else the earliest head `ready_at`.
    fn reschedule_node(&mut self, node: usize) {
        if self.node_flits[node] == 0 {
            self.contenders[node] = 0;
            return;
        }
        let mut mask = 0u8;
        let mut next = u64::MAX;
        for port in 0..PORTS {
            let idx = node * PORTS + port;
            let d = self.desired[idx];
            if d != NO_DESIRE {
                mask |= 1 << d;
            }
            if let Some(f) = self.buffers[idx].front() {
                next = next.min(f.ready_at.max(self.now + 1));
            }
        }
        self.contenders[node] = mask;
        if next != u64::MAX {
            self.schedule_wake(node, next);
        }
    }

    /// Plan the multi-hop segment for a flit leaving `node` in direction
    /// `d` into the caller's stack buffer (no allocation on the hot path);
    /// returns the path length (0 = no move possible this cycle).
    fn plan_segment(&self, node: usize, d: Dir, f: &Flit, path: &mut [usize; MAX_SEG]) -> usize {
        let p = self.table.get(f.pkt);
        let dst = p.dst as usize;
        // Maximum run: wormhole = 1; SMART = up to hpc_max along the
        // current straight run; body flits go exactly to their next stop.
        let max_run = if f.is_head() {
            self.hpc_max.min(self.topo.straight_run(node, dst)).max(1)
        } else {
            let next = p.stops[f.seg as usize + 1] as usize;
            self.topo.hops(node, next)
        };
        debug_assert!(max_run <= MAX_SEG);
        let mut len = 0usize;
        let mut at = node;
        for hop in 0..max_run {
            // Link must be free this cycle.
            if self.link_stamp[self.topo.link_id(at, d)] == self.now {
                break;
            }
            let next = match self.topo.neighbor(at, d) {
                Some(n) => n,
                None => break, // mesh edge (cannot happen on minimal routes)
            };
            // Bypass conditions at the router we'd pass *through* (not the
            // final stop of this iteration): output must not be locked by
            // another packet, and no buffered local flit may want it.
            if hop + 1 < max_run {
                let lock = self.out_lock[next * PORTS + d.index()];
                // SSR priority (head flits only): a buffered local flit at
                // an intermediate router truncates the bypass. Body flits
                // travel a path their head already reserved (locked), so
                // they never yield — yielding to a contender that is itself
                // blocked on this packet's lock would deadlock.
                let blocked = matches!(lock, Some(owner) if owner != f.pkt)
                    || (f.is_head() && self.has_local_contender(next, d))
                    || self.link_stamp[self.topo.link_id(next, d)] == self.now;
                path[len] = next;
                len += 1;
                if blocked {
                    break;
                }
            } else {
                path[len] = next;
                len += 1;
            }
            at = next;
        }
        // Truncate to the furthest router with buffer space (or the dst,
        // which ejects through its own buffer too). Body flits move their
        // segment atomically: if they cannot reach their recorded stop they
        // wait (slightly pessimistic, preserves flit order).
        if f.is_head() {
            while len > 0 {
                let stop = path[len - 1];
                if self.buf(stop, d.opposite()).len() < self.buffer_depth {
                    break;
                }
                len -= 1;
            }
        } else if len > 0 {
            let stop = path[len - 1];
            let next = p.stops[f.seg as usize + 1] as usize;
            if stop != next || self.buf(stop, d.opposite()).len() >= self.buffer_depth {
                len = 0;
            }
        }
        len
    }

    /// Advance one cycle, event-driven: only routers with a due wakeup and
    /// sources with queued packets are touched. Observable behavior is
    /// identical to [`Self::step_reference`] (golden parity test).
    pub fn step(&mut self) {
        if self.buffered > 0 {
            // Pass 0: collect due wakeups, ascending node order (the seed
            // engine allocates links/locks scanning nodes 0..n in order, so
            // the woken subset must be visited in that same order). The
            // scratch vector is moved out of `self` for the duration so the
            // borrow checker allows &mut self calls while iterating it.
            let mut woken = std::mem::take(&mut self.woken);
            woken.clear();
            while let Some(&Reverse((t, node))) = self.wake.peek() {
                if t > self.now {
                    break;
                }
                self.wake.pop();
                if self.wake_at[node as usize] == t {
                    self.wake_at[node as usize] = u64::MAX;
                    woken.push(node);
                }
            }
            woken.sort_unstable();
            // Pass 1: SSR snapshot (broadcast a cycle ahead of traversal —
            // all desires are computed before any flit moves).
            for &node in &woken {
                self.refresh_node(node as usize);
            }
            // Pass 2: switch allocation + traversal in fixed node order.
            for &node in &woken {
                if self.contenders[node as usize] != 0 {
                    self.route_node(node as usize);
                }
            }
            // Pass 3: restore mask invariants and schedule next wakeups.
            for &node in &woken {
                self.reschedule_node(node as usize);
            }
            self.woken = woken;
        }

        // Injection: one flit per node per cycle from each non-empty
        // source queue.
        if self.src_pkts > 0 {
            self.inject_active();
        }

        self.now += 1;
    }

    /// Advance one cycle with the seed cycle-stepped engine: snapshot and
    /// scan every router. Kept as the golden reference for parity tests;
    /// do not mix with [`Self::step`] on the same instance (this path does
    /// not maintain the wakeup calendar).
    pub fn step_reference(&mut self) {
        if self.buffered > 0 {
            self.snapshot_desires();
            // Switch allocation + traversal, router by router in fixed order.
            for node in 0..self.topo.nodes() {
                // Idle routers (no buffered flits) are skipped outright.
                if self.contenders[node] != 0 {
                    self.route_node(node);
                }
            }
        }
        if self.src_pkts > 0 {
            for node in 0..self.topo.nodes() {
                self.inject_node(node);
            }
        }
        self.now += 1;
    }

    fn route_node(&mut self, node: usize) {
        // For each output port, pick one input whose head flit is ready and
        // wants this output (round-robin over the SSR snapshot), then try
        // to move it.
        for out in [Dir::Local, Dir::East, Dir::West, Dir::North, Dir::South] {
            let oi = out.index() as u8;
            if self.contenders[node] & (1 << oi) == 0 {
                continue;
            }
            let out_idx = node * PORTS + out.index();
            let start = self.rr[out_idx];
            let mut winner: Option<usize> = None;
            for k in 0..PORTS {
                let port = (start + k) % PORTS;
                if self.desired[node * PORTS + port] == oi {
                    // Wormhole lock: output must be free or ours.
                    let f = self.buffers[node * PORTS + port].front().unwrap();
                    let lock = self.out_lock[out_idx];
                    if matches!(lock, Some(owner) if owner != f.pkt) {
                        continue;
                    }
                    winner = Some(port);
                    break;
                }
            }
            let Some(port) = winner else { continue };
            let moved = self.try_move(node, port, out);
            if moved {
                self.rr[out_idx] = (port + 1) % PORTS;
                // The port routed this cycle; its next head waits a cycle.
                self.desired[node * PORTS + port] = NO_DESIRE;
            }
        }
    }

    /// Attempt to move the head-of-buffer flit at (`node`, `port`) out via
    /// `out`. Returns true if the flit moved (or ejected).
    fn try_move(&mut self, node: usize, port: usize, out: Dir) -> bool {
        let f = *self.buffers[node * PORTS + port].front().unwrap();
        if out == Dir::Local {
            // Ejection: one flit per node per cycle.
            if self.eject_stamp[node] == self.now {
                return false;
            }
            self.eject_stamp[node] = self.now;
            self.buffers[node * PORTS + port].pop_front();
            self.buffered -= 1;
            self.node_flits[node] -= 1;
            self.flits_ejected += 1;
            let now = self.now;
            let (done, latency) = {
                let p = self.table.get_mut(f.pkt);
                p.delivered += 1;
                if p.delivered == p.len {
                    p.done_cycle = now;
                }
                (p.delivered == p.len, now.saturating_sub(p.inject_cycle))
            };
            if done && self.tracing() {
                self.trace_instant(
                    node,
                    "eject",
                    vec![("pkt", f.pkt as u64), ("latency", latency)],
                );
            }
            return true;
        }

        let mut seg = [0usize; MAX_SEG];
        let len = self.plan_segment(node, out, &f, &mut seg);
        if len == 0 {
            return false;
        }
        let path = &seg[..len];
        let stop = path[len - 1];
        // SMART observability: a head flit committing a multi-hop segment
        // is a bypass (intermediate router pipelines skipped); a one-hop
        // segment is an ordinary wormhole hop. Body flits replay the
        // head's segmentation and are not re-reported.
        if f.is_head() && self.tracing() {
            let name = if len > 1 { "bypass" } else { "hop" };
            self.trace_instant(
                node,
                name,
                vec![("pkt", f.pkt as u64), ("hops", len as u64), ("to", stop as u64)],
            );
        }
        // Commit: consume links, update locks, move the flit. The whole
        // traversed segment is locked packet-wise (the SSR reserves the
        // path): locking only the segment-start output would let another
        // packet's flits interleave at an intermediate router and deadlock
        // single-VC wormhole (found by the delivery property test).
        let is_tail = {
            let p = self.table.get(f.pkt);
            f.idx == p.len - 1
        };
        let mut at = node;
        for &next in path {
            let lid = self.topo.link_id(at, out);
            debug_assert!(self.link_stamp[lid] != self.now);
            self.link_stamp[lid] = self.now;
            let oidx = at * PORTS + out.index();
            debug_assert!(self.out_lock[oidx].is_none() || self.out_lock[oidx] == Some(f.pkt));
            self.out_lock[oidx] = if is_tail { None } else { Some(f.pkt) };
            at = next;
        }
        let mut moved = self.buffers[node * PORTS + port].pop_front().unwrap();
        if moved.is_head() {
            let p = self.table.get_mut(moved.pkt);
            p.stops.push(stop as u32);
            moved.seg = (p.stops.len() - 1) as u16;
        } else {
            moved.seg += 1;
        }
        moved.ready_at = self.now + 1 + self.router_latency;
        let wake_t = moved.ready_at.max(self.now + 1);
        self.buffers[stop * PORTS + out.opposite().index()].push_back(moved);
        self.node_flits[node] -= 1;
        self.node_flits[stop] += 1;
        self.schedule_wake(stop, wake_t);
        true
    }

    /// Inject from every node with a non-empty source queue, retiring
    /// nodes whose queue drains (event-driven injection scan).
    fn inject_active(&mut self) {
        let mut i = 0;
        while i < self.active_src.len() {
            let node = self.active_src[i] as usize;
            self.inject_node(node);
            if self.src_q[node].is_empty() {
                self.src_active[node] = false;
                self.active_src.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }

    fn inject_node(&mut self, node: usize) {
        let Some(&pkt) = self.src_q[node].front() else {
            return;
        };
        let local = node * PORTS + Dir::Local.index();
        if self.buffers[local].len() >= self.buffer_depth {
            return;
        }
        let idx = self.src_next_flit[node];
        let (len, dst) = {
            let p = self.table.get_mut(pkt);
            if p.inject_cycle == u64::MAX {
                p.inject_cycle = self.now;
            }
            (p.len, p.dst)
        };
        if idx == 0 && self.tracing() {
            self.trace_instant(
                node,
                "inject",
                vec![("pkt", pkt as u64), ("dst", dst as u64), ("len", len as u64)],
            );
        }
        let ready_at = self.now + self.router_latency;
        self.buffers[local].push_back(Flit {
            pkt,
            idx,
            seg: 0,
            ready_at,
        });
        self.buffered += 1;
        self.node_flits[node] += 1;
        self.flits_injected += 1;
        self.schedule_wake(node, ready_at.max(self.now + 1));
        if idx + 1 == len {
            self.src_q[node].pop_front();
            self.src_pkts -= 1;
            self.src_next_flit[node] = 0;
        } else {
            self.src_next_flit[node] = idx + 1;
        }
    }

    /// Debug aid: print the first `limit` stuck buffer heads and any locks.
    pub fn debug_dump(&self, limit: usize) {
        let mut shown = 0;
        for node in 0..self.topo.nodes() {
            for port in 0..PORTS {
                if let Some(f) = self.buffers[node * PORTS + port].front() {
                    if shown >= limit {
                        return;
                    }
                    shown += 1;
                    let p = self.table.get(f.pkt);
                    let out = self.desired_out(node, f);
                    let lock = self.out_lock[node * PORTS + out.index()];
                    println!(
                        "node {node} port {port}: pkt {} idx {} seg {} ready {} \
                         dst {} stops {:?} -> out {:?} lock {:?} qlen {}",
                        f.pkt,
                        f.idx,
                        f.seg,
                        f.ready_at,
                        p.dst,
                        p.stops,
                        out,
                        lock,
                        self.buffers[node * PORTS + port].len()
                    );
                }
            }
        }
    }

    /// Run until quiescent or `max_cycles` elapse; returns cycles run.
    /// Event-driven: spans with no due wakeup and no pending injections are
    /// skipped in one jump (each skipped cycle is provably a no-op).
    pub fn drain(&mut self, max_cycles: u64) -> u64 {
        let start = self.now;
        while !self.quiescent() && self.now - start < max_cycles {
            if self.src_pkts == 0 {
                match self.next_wake() {
                    Some(t) if t > self.now => {
                        self.now = t.min(start + max_cycles);
                        if self.now - start >= max_cycles {
                            break;
                        }
                    }
                    Some(_) => {}
                    // Buffered flits with an empty wakeup calendar violates
                    // the engine invariant (every landing schedules one):
                    // loud in debug builds so the parity suite catches it,
                    // bounded (not spinning) in release.
                    None => {
                        debug_assert!(
                            false,
                            "event engine: {} buffered flits but no pending wakeup",
                            self.buffered
                        );
                        break;
                    }
                }
            }
            self.step();
        }
        self.now - start
    }

    /// Seed-engine drain: cycle-stepped, no event skipping. Pairs with
    /// [`Self::step_reference`] for the golden parity tests.
    pub fn drain_reference(&mut self, max_cycles: u64) -> u64 {
        let start = self.now;
        while !self.quiescent() && self.now - start < max_cycles {
            self.step_reference();
        }
        self.now - start
    }

    /// Earliest future cycle at which the network can change state, or
    /// `None` when quiescent. `Some(now)` means there is work this cycle.
    pub fn next_event(&mut self) -> Option<u64> {
        if self.src_pkts > 0 {
            return Some(self.now);
        }
        if self.buffered == 0 {
            return None;
        }
        let now = self.now;
        self.next_wake().map(|t| t.max(now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::Mesh;

    fn net(hpc: usize) -> Network {
        Network::new(Mesh::new(8, 8), hpc, 1, 4)
    }

    #[test]
    fn single_packet_delivers_wormhole() {
        let mut n = net(1);
        let id = n.enqueue(0, 63, 5);
        let cycles = n.drain(10_000);
        assert!(n.quiescent(), "not drained after {cycles}");
        let p = n.table.get(id);
        assert!(p.is_done());
        assert_eq!(p.delivered, 5);
        // 14 hops, >= hops + serialization.
        assert!(p.net_latency() >= 14 + 4, "latency {}", p.net_latency());
    }

    #[test]
    fn smart_is_faster_than_wormhole_uncontended() {
        let run = |hpc| {
            let mut n = net(hpc);
            let id = n.enqueue(0, 63, 5);
            n.drain(10_000);
            n.table.get(id).net_latency()
        };
        let worm = run(1);
        let smart = run(14);
        assert!(
            smart < worm / 2,
            "smart {smart} should be far below wormhole {worm}"
        );
    }

    #[test]
    fn smart_head_respects_hpc_max() {
        // A straight 7-hop route with HPC_max 4 needs exactly 2 stops.
        let mut n = net(4);
        let id = n.enqueue(0, 7, 1); // nodes 0..7 on row 0
        n.drain(1_000);
        let p = n.table.get(id);
        assert!(p.is_done());
        // stops = [src, 4 hops, 3 hops] = [0, 4, 7]
        assert_eq!(p.stops, vec![0, 4, 7]);
    }

    #[test]
    fn every_packet_delivered_exactly_once_under_load() {
        let mut n = net(8);
        let mut expect = Vec::new();
        for i in 0..200u32 {
            let src = (i as usize * 7) % 64;
            let dst = (i as usize * 13 + 1) % 64;
            if src != dst {
                expect.push(n.enqueue(src, dst, 3));
            }
            n.step();
        }
        n.drain(100_000);
        assert!(n.quiescent());
        for id in expect {
            let p = n.table.get(id);
            assert!(p.is_done(), "packet {id} not done");
            assert_eq!(p.delivered, 3, "packet {id} flits {}", p.delivered);
        }
    }

    #[test]
    fn stop_lists_are_monotone_routes() {
        // All stops must lie on the XY route, strictly progressing.
        let mut n = net(6);
        let ids: Vec<u32> = (0..50)
            .filter_map(|i| {
                let src = (i * 11) % 64;
                let dst = (i * 29 + 5) % 64;
                (src != dst).then(|| n.enqueue(src, dst, 4))
            })
            .collect();
        n.drain(100_000);
        for id in ids {
            let p = n.table.get(id);
            let mut remaining = n.topo.hops(p.src as usize, p.dst as usize);
            for w in p.stops.windows(2) {
                let step = n.topo.hops(w[0] as usize, w[1] as usize);
                assert!(step >= 1);
                let new_rem = n.topo.hops(w[1] as usize, p.dst as usize);
                assert_eq!(new_rem + step, remaining, "non-minimal segment");
                remaining = new_rem;
            }
            assert_eq!(*p.stops.last().unwrap(), p.dst);
        }
    }

    #[test]
    fn wormhole_no_flit_interleaving_on_outputs() {
        // With single-flit packets this is trivial; with 4-flit packets the
        // lock must hold: drain and verify all done (liveness under locks).
        let mut n = net(1);
        for src in 0..32usize {
            n.enqueue(src, 63 - src, 4);
        }
        n.drain(200_000);
        assert!(n.quiescent(), "wormhole deadlocked");
    }

    #[test]
    fn injection_serializes_one_flit_per_cycle() {
        let mut n = net(1);
        n.enqueue(0, 1, 4);
        n.step();
        assert_eq!(n.flits_injected, 1);
        n.step();
        assert_eq!(n.flits_injected, 2);
    }

    #[test]
    fn event_and_reference_steps_agree_cycle_by_cycle() {
        // Drive two identical networks through the same injection schedule,
        // one per engine; every packet's full trajectory must match.
        let mut ev = net(8);
        let mut re = net(8);
        for i in 0..150u32 {
            let src = (i as usize * 11 + 3) % 64;
            let dst = (i as usize * 23 + 40) % 64;
            if src != dst {
                ev.enqueue(src, dst, 1 + (i % 5) as u16);
                re.enqueue(src, dst, 1 + (i % 5) as u16);
            }
            ev.step();
            re.step_reference();
            assert_eq!(ev.flits_ejected, re.flits_ejected, "cycle {i}");
        }
        ev.drain(100_000);
        re.drain_reference(100_000);
        assert!(ev.quiescent() && re.quiescent());
        assert_eq!(ev.table.len(), re.table.len());
        for id in 0..ev.table.len() as u32 {
            let (a, b) = (ev.table.get(id), re.table.get(id));
            assert_eq!(a.inject_cycle, b.inject_cycle, "pkt {id}");
            assert_eq!(a.done_cycle, b.done_cycle, "pkt {id}");
            assert_eq!(a.stops, b.stops, "pkt {id}");
        }
    }

    #[test]
    fn drain_event_skip_matches_reference_drain() {
        // One long-haul packet with a deep router pipeline: the event drain
        // must jump the pipeline bubbles yet finish at the same cycle.
        let mut ev = Network::new(Mesh::new(8, 8), 1, 6, 2);
        let mut re = Network::new(Mesh::new(8, 8), 1, 6, 2);
        let a = ev.enqueue(0, 63, 3);
        let b = re.enqueue(0, 63, 3);
        ev.drain(50_000);
        re.drain_reference(50_000);
        assert_eq!(
            ev.table.get(a).done_cycle,
            re.table.get(b).done_cycle,
            "event-skip drain diverged"
        );
    }

    #[test]
    fn next_event_none_when_quiescent() {
        let mut n = net(4);
        assert_eq!(n.next_event(), None);
        n.enqueue(0, 5, 2);
        assert_eq!(n.next_event(), Some(n.now));
        n.drain(10_000);
        assert!(n.quiescent());
        assert_eq!(n.next_event(), None);
    }
}
