//! Flit-level cycle-accurate NoC simulator (the garnet2.0 substitute,
//! DESIGN.md §1): 2D mesh, XY routing, wormhole flow control, SMART
//! single-cycle multi-hop bypass, and an ideal interconnect, plus the six
//! synthetic traffic patterns of Sec. VII.

pub mod ideal;
pub mod network;
pub mod packet;
pub mod sim;
pub mod topology;
pub mod traffic;

pub use ideal::IdealNet;
pub use network::Network;
pub use sim::{run_flows, run_synthetic, NocModel, NocStats, SyntheticConfig};
pub use topology::{Dir, Mesh};
pub use traffic::{Flow, Pattern};
