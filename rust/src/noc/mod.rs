//! Flit-level cycle-accurate NoC simulator (the garnet2.0 substitute,
//! DESIGN.md §1): pluggable topologies ([`Mesh2D`] — the paper's fabric —
//! plus [`Torus2D`] and [`PrismCnn`] behind the [`Topology`] trait),
//! minimal deterministic routing, wormhole flow control, SMART
//! single-cycle multi-hop bypass, and an ideal interconnect, plus the six
//! synthetic traffic patterns of Sec. VII.
//!
//! Every interconnect implements the [`NocBackend`] trait; the flit engine
//! is event-driven (a wakeup calendar skips idle routers) with the seed
//! cycle-stepped engine retained as a golden reference (DESIGN.md §1).

pub mod backend;
pub mod ideal;
pub mod network;
pub mod packet;
pub mod sim;
pub mod topology;
pub mod traffic;

pub use backend::{build_backend, NocBackend};
pub use ideal::IdealNet;
pub use network::Network;
pub use sim::{
    run_flows, run_flows_detailed_traced, run_synthetic, run_synthetic_traced, run_synthetic_with,
    NocStats, StepMode, SyntheticConfig,
};
pub use topology::{AnyTopology, Dir, Mesh, Mesh2D, PrismCnn, Topology, Torus2D};
pub use traffic::{Flow, Pattern};
