//! Ideal interconnect (Sec. VI-B's "ideal" NoC): a topology-free upper
//! bound — every packet crosses the fabric in one hop (`t_w x 1` in
//! Eq. (3)), with only injection/ejection serialization and zero
//! in-network contention. It deliberately ignores the configured
//! [`Topology`](super::Topology) (only the endpoint count matters), so it
//! bounds every topology's latency from below.

use crate::obs::trace::{SharedSink, TraceEvent, TracePhase};

use super::packet::PacketTable;

/// Analytic ideal network with the same driver interface as [`super::Network`]
/// (both implement [`super::backend::NocBackend`]).
pub struct IdealNet {
    nodes: usize,
    /// Next cycle each source's injection port is free.
    src_free: Vec<u64>,
    /// Next cycle each destination's ejection port is free.
    dst_free: Vec<u64>,
    /// All packets ever injected (stats source).
    pub table: PacketTable,
    /// Current cycle.
    pub now: u64,
    /// Total flits accepted at sources.
    pub flits_injected: u64,
    /// Total flits delivered at sinks.
    pub flits_ejected: u64,
    /// (eject_cycle, pkt, flit_idx) min-heap substitute: sorted insertion is
    /// overkill; we keep a simple bucket queue keyed by cycle.
    pending: std::collections::BTreeMap<u64, Vec<u32>>,
    /// Optional trace sink (observational only; `None` = no overhead).
    trace: Option<SharedSink>,
}

impl IdealNet {
    /// An ideal fabric over `nodes` endpoints.
    pub fn new(nodes: usize) -> Self {
        Self {
            nodes,
            src_free: vec![0; nodes],
            dst_free: vec![0; nodes],
            table: PacketTable::default(),
            now: 0,
            flits_injected: 0,
            flits_ejected: 0,
            pending: std::collections::BTreeMap::new(),
            trace: None,
        }
    }

    /// Report packet inject/eject events (subsystem `"noc"`, track =
    /// endpoint) to `sink`. Observational only: delivery schedules and
    /// stats stay bit-identical.
    pub fn attach_trace(&mut self, sink: SharedSink) {
        self.trace = Some(sink);
    }

    fn trace_instant(
        &self,
        node: usize,
        name: &'static str,
        ts: u64,
        args: Vec<(&'static str, u64)>,
    ) {
        if let Some(t) = &self.trace {
            let mut t = t.borrow_mut();
            if t.enabled() {
                t.record(TraceEvent {
                    subsystem: "noc",
                    track: node as u64,
                    name,
                    ts,
                    phase: TracePhase::Instant,
                    args,
                });
            }
        }
    }

    /// Queue a packet; its delivery schedule is computed analytically:
    /// flit i leaves src at `max(now, src_free) + i`, flies one hop
    /// (1 cycle), and ejects when the dst port is free.
    pub fn enqueue(&mut self, src: usize, dst: usize, len: u16) -> u32 {
        debug_assert!(src != dst);
        let id = self.table.add(src as u32, dst as u32, len, self.now);
        let start = self.src_free[src].max(self.now);
        let mut done = 0;
        for i in 0..len as u64 {
            let leave = start + i;
            let arrive = leave + 1;
            let eject = arrive.max(self.dst_free[dst]);
            self.dst_free[dst] = eject + 1;
            done = eject;
        }
        self.src_free[src] = start + len as u64;
        let p = self.table.get_mut(id);
        p.inject_cycle = start;
        p.stops.push(dst as u32);
        self.pending.entry(done).or_default().push(id);
        self.flits_injected += len as u64;
        self.trace_instant(
            src,
            "inject",
            start,
            vec![("pkt", id as u64), ("dst", dst as u64), ("len", len as u64)],
        );
        id
    }

    /// Advance one cycle: complete packets whose tail ejects now.
    pub fn step(&mut self) {
        self.now += 1;
        let due: Vec<u64> = self
            .pending
            .range(..=self.now)
            .map(|(&c, _)| c)
            .collect();
        for c in due {
            for id in self.pending.remove(&c).unwrap() {
                let (dst, latency) = {
                    let p = self.table.get_mut(id);
                    p.delivered = p.len;
                    p.done_cycle = c;
                    self.flits_ejected += p.len as u64;
                    (p.dst, c.saturating_sub(p.inject_cycle))
                };
                self.trace_instant(
                    dst as usize,
                    "eject",
                    c,
                    vec![("pkt", id as u64), ("latency", latency)],
                );
            }
        }
    }

    /// True when no packet is still in flight.
    pub fn quiescent(&self) -> bool {
        self.pending.is_empty()
    }

    /// Earliest cycle at which a pending tail ejects; `None` when idle.
    /// All delivery schedules are precomputed at enqueue, so this *is* the
    /// full event calendar.
    pub fn next_event(&mut self) -> Option<u64> {
        self.pending.keys().next().copied()
    }

    /// Run until all pending packets are delivered or `max_cycles` elapse.
    /// Event-driven: jumps straight to the next scheduled ejection (every
    /// skipped cycle is a no-op by construction of the analytic schedule).
    pub fn drain(&mut self, max_cycles: u64) -> u64 {
        let start = self.now;
        while !self.quiescent() && self.now - start < max_cycles {
            if let Some(&t) = self.pending.keys().next() {
                // step() first increments the clock, so park one cycle shy.
                let target = (t - 1).min(start + max_cycles);
                if target > self.now {
                    self.now = target;
                }
                if self.now - start >= max_cycles {
                    break;
                }
            }
            self.step();
        }
        self.now - start
    }

    /// Endpoint count.
    pub fn n_nodes(&self) -> usize {
        self.nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_packet_latency_is_hopless() {
        let mut n = IdealNet::new(64);
        let id = n.enqueue(0, 63, 5);
        n.drain(1_000);
        let p = n.table.get(id);
        assert!(p.is_done());
        // head leaves at 0, tail at 4, arrives 5: latency 5 = len cycles.
        assert_eq!(p.net_latency(), 5);
    }

    #[test]
    fn ejection_port_serializes() {
        let mut n = IdealNet::new(64);
        let a = n.enqueue(0, 5, 4);
        let b = n.enqueue(1, 5, 4);
        n.drain(1_000);
        // Eight flits through one ejection port: second packet waits.
        let (ta, tb) = (n.table.get(a).done_cycle, n.table.get(b).done_cycle);
        assert!(tb >= ta + 4, "a={ta} b={tb}");
    }

    #[test]
    fn injection_port_serializes() {
        let mut n = IdealNet::new(64);
        let a = n.enqueue(0, 5, 4);
        let b = n.enqueue(0, 9, 4);
        n.drain(1_000);
        assert!(n.table.get(b).inject_cycle >= n.table.get(a).inject_cycle + 4);
    }

    #[test]
    fn quiescent_after_drain() {
        let mut n = IdealNet::new(16);
        for i in 0..10 {
            n.enqueue(i % 16, (i + 3) % 16, 2);
        }
        n.drain(10_000);
        assert!(n.quiescent());
        assert_eq!(n.flits_injected, n.flits_ejected);
    }

    #[test]
    fn event_drain_matches_stepped_drain() {
        // Same packet set through the jumpy drain and a manual step loop:
        // identical completion cycles and identical elapsed-clock result.
        let mut jump = IdealNet::new(16);
        let mut walk = IdealNet::new(16);
        for i in 0..12 {
            jump.enqueue(i % 16, (i + 5) % 16, 1 + (i % 4) as u16);
            walk.enqueue(i % 16, (i + 5) % 16, 1 + (i % 4) as u16);
        }
        jump.drain(10_000);
        while !walk.quiescent() {
            walk.step();
        }
        for id in 0..jump.table.len() as u32 {
            assert_eq!(
                jump.table.get(id).done_cycle,
                walk.table.get(id).done_cycle,
                "packet {id}"
            );
        }
        assert_eq!(jump.flits_ejected, walk.flits_ejected);
    }

    #[test]
    fn drain_respects_cycle_budget() {
        let mut n = IdealNet::new(64);
        n.enqueue(0, 63, 4); // tail ejects at cycle 5
        let ran = n.drain(2);
        assert_eq!(ran, 2);
        assert!(!n.quiescent(), "budget must cap the jump");
        n.drain(1_000);
        assert!(n.quiescent());
    }
}
