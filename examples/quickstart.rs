//! Quickstart: simulate one benchmark point of the paper in ~10 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::sim::evaluate;

fn main() {
    // The paper's node: 320 tiles of 12 cores x 8 ReRAM subarrays.
    let arch = ArchConfig::paper_node();

    // Best case of Fig. 8: VGG-E with weight replication + batch
    // pipelining on the SMART NoC.
    let report = evaluate(
        VggVariant::E,
        Scenario::ReplicationBatch,
        NocKind::Smart,
        &arch,
    );

    println!("VGG-E, scenario (4), SMART NoC:");
    println!("  injection interval : {:.0} logical cycles", report.interval_cycles);
    println!("  per-image latency  : {:.0} logical cycles", report.latency_cycles);
    println!("  throughput         : {:.0} FPS = {:.4} TOPS", report.fps, report.tops);
    println!("  energy / image     : {:.2} mJ", report.energy.total_mj());
    println!("  efficiency         : {:.4} TOPS/W", report.tops_per_watt);
    println!();
    println!("paper (Fig. 8, smart/(4)): 40.4027 TOPS, 1029 FPS; Fig. 9: 3.5914 TOPS/W");
}
