//! Design-space exploration: how does the weight-replication budget shape
//! throughput? Sweeps the heuristic auto-planner's max replication factor
//! for each VGG and compares against both the paper's hand-tuned Fig. 7
//! plans and the searched planner (`smart_pim::planner`) — the ablation
//! behind the paper's "balanced pipeline design" claim (Sec. VI-C), plus
//! the evidence that a searched mapping beats the hand-derived one.
//!
//! ```bash
//! cargo run --release --example replication_sweep
//! ```

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{plan_tiles, NetworkMapping, ReplicationPlan};
use smart_pim::pipeline::build_plans;
use smart_pim::sim::engine::{Engine, NocAdjust};
use smart_pim::sweep::SweepRunner;
use smart_pim::util::table::{fnum, Table};

/// Which plan a sweep point evaluates.
#[derive(Clone, Copy)]
enum PlanKind {
    /// Heuristic pooling-trend planner capped at this factor.
    Auto(usize),
    /// The paper's hand-tuned Fig. 7 plan.
    Fig7,
    /// The searched planner at the full 320-tile budget.
    Searched,
}

fn throughput_fps(arch: &ArchConfig, v: VggVariant, plan: &ReplicationPlan) -> (f64, usize) {
    let net = vgg::build(v);
    let tiles = plan_tiles(&net, arch, &plan.factors);
    let m = NetworkMapping::build(&net, arch, plan).expect("plan must fit");
    let plans = build_plans(&net, &m, arch);
    let adj = NocAdjust::identity(plans.len());
    let sim = Engine::new(&plans, &adj, true, 8).run();
    // 8-image runs always have a steady interval, but stay panic-free.
    let interval = sim.interval_or_makespan();
    let fps = 1.0 / (interval * arch.logical_cycle_ns * 1e-9);
    (fps, tiles)
}

fn main() {
    let arch = ArchConfig::paper_node();

    // The whole design space is one parallel sweep: every (VGG, plan)
    // point is independent, so fan them out across cores.
    let max_rs = [1usize, 2, 4, 8, 16];
    let mut points: Vec<(VggVariant, PlanKind)> = Vec::new();
    for v in VggVariant::ALL {
        for r in max_rs {
            points.push((v, PlanKind::Auto(r)));
        }
        points.push((v, PlanKind::Fig7));
        points.push((v, PlanKind::Searched));
    }
    let runner = SweepRunner::new();
    let results = runner.run(&points, |_, &(v, kind)| {
        let net = vgg::build(v);
        let plan = match kind {
            PlanKind::Auto(r) => ReplicationPlan::auto(&net, &arch, r),
            PlanKind::Fig7 => ReplicationPlan::fig7(v),
            PlanKind::Searched => {
                ReplicationPlan::searched(&net, &arch, arch.total_tiles())
                    .expect("VGGs fit the paper node")
            }
        };
        throughput_fps(&arch, v, &plan)
    });

    let mut t = Table::new(
        "planner sweep: FPS (tiles used) by plan",
        &[
            "vgg", "r<=1", "r<=2", "r<=4", "r<=8", "r<=16", "fig7 hand plan", "searched",
        ],
    );
    let per_vgg = max_rs.len() + 2;
    for (vi, v) in VggVariant::ALL.iter().enumerate() {
        let mut row = vec![v.name().to_string()];
        for (fps, tiles) in &results[vi * per_vgg..(vi + 1) * per_vgg] {
            row.push(format!("{} ({tiles})", fnum(*fps, 0)));
        }
        t.row(&row);
    }
    t.print();

    println!();
    println!("Ablation — what if conv1 were NOT replicated 16x (VGG-E)?");
    let mut t = Table::new(
        "conv1 replication ablation (others per Fig. 7)",
        &["conv1 r", "interval (cycles)", "FPS"],
    );
    for r1 in [1usize, 2, 4, 8, 16] {
        let mut plan = ReplicationPlan::fig7(VggVariant::E);
        plan.factors[0] = r1;
        let net = vgg::build(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let plans = build_plans(&net, &m, &arch);
        let adj = NocAdjust::identity(plans.len());
        let sim = Engine::new(&plans, &adj, true, 8).run();
        let interval = sim.interval_or_makespan();
        t.row(&[
            format!("{r1}"),
            fnum(interval, 0),
            fnum(1.0 / (interval * arch.logical_cycle_ns * 1e-9), 0),
        ]);
    }
    t.print();
    println!("(the busiest stage gates the whole pipeline: balance, not peak, wins)");
}
