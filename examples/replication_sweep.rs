//! Design-space exploration: how does the weight-replication budget shape
//! throughput? Sweeps the auto-planner's max replication factor for each
//! VGG and compares against the paper's hand-tuned Fig. 7 plans — the
//! ablation behind the paper's "balanced pipeline design" claim (Sec. VI-C).
//!
//! ```bash
//! cargo run --release --example replication_sweep
//! ```

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{plan_tiles, NetworkMapping, ReplicationPlan};
use smart_pim::pipeline::build_plans;
use smart_pim::sim::engine::{Engine, NocAdjust};
use smart_pim::sweep::SweepRunner;
use smart_pim::util::table::{fnum, Table};

fn throughput_fps(arch: &ArchConfig, v: VggVariant, plan: &ReplicationPlan) -> (f64, usize) {
    let net = vgg::build(v);
    let tiles = plan_tiles(&net, arch, &plan.factors);
    let m = NetworkMapping::build(&net, arch, plan).expect("plan must fit");
    let plans = build_plans(&net, &m, arch);
    let adj = NocAdjust::identity(plans.len());
    let sim = Engine::new(&plans, &adj, true, 8).run();
    let interval = sim.steady_interval().expect("8 images give an interval");
    let fps = 1.0 / (interval * arch.logical_cycle_ns * 1e-9);
    (fps, tiles)
}

fn main() {
    let arch = ArchConfig::paper_node();

    // The whole design space is one parallel sweep: every (VGG, budget)
    // point is independent, so fan them out across cores.
    let max_rs = [1usize, 2, 4, 8, 16];
    let mut points: Vec<(VggVariant, Option<usize>)> = Vec::new();
    for v in VggVariant::ALL {
        for r in max_rs {
            points.push((v, Some(r))); // auto-planner with budget r
        }
        points.push((v, None)); // the paper's hand-tuned Fig. 7 plan
    }
    let runner = SweepRunner::new();
    let results = runner.run(&points, |_, &(v, max_r)| {
        let net = vgg::build(v);
        let plan = match max_r {
            Some(r) => ReplicationPlan::auto(&net, &arch, r),
            None => ReplicationPlan::fig7(v),
        };
        throughput_fps(&arch, v, &plan)
    });

    let mut t = Table::new(
        "auto-planner sweep: FPS (tiles used) by max replication factor",
        &["vgg", "r<=1", "r<=2", "r<=4", "r<=8", "r<=16", "fig7 hand plan"],
    );
    let per_vgg = max_rs.len() + 1;
    for (vi, v) in VggVariant::ALL.iter().enumerate() {
        let mut row = vec![v.name().to_string()];
        for (fps, tiles) in &results[vi * per_vgg..(vi + 1) * per_vgg] {
            row.push(format!("{} ({tiles})", fnum(*fps, 0)));
        }
        t.row(&row);
    }
    t.print();

    println!();
    println!("Ablation — what if conv1 were NOT replicated 16x (VGG-E)?");
    let mut t = Table::new(
        "conv1 replication ablation (others per Fig. 7)",
        &["conv1 r", "interval (cycles)", "FPS"],
    );
    for r1 in [1usize, 2, 4, 8, 16] {
        let mut plan = ReplicationPlan::fig7(VggVariant::E);
        plan.factors[0] = r1;
        let net = vgg::build(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let plans = build_plans(&net, &m, &arch);
        let adj = NocAdjust::identity(plans.len());
        let sim = Engine::new(&plans, &adj, true, 8).run();
        let interval = sim.steady_interval().expect("8 images give an interval");
        t.row(&[
            format!("{r1}"),
            fnum(interval, 0),
            fnum(1.0 / (interval * arch.logical_cycle_ns * 1e-9), 0),
        ]);
    }
    t.print();
    println!("(the busiest stage gates the whole pipeline: balance, not peak, wins)");
}
