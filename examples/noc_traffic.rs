//! NoC deep-dive: sweep a synthetic traffic pattern across injection rates
//! on the 8x8 mesh and print the Fig. 10/11 curves for wormhole vs SMART
//! vs ideal, plus an HPC_max ablation (how far the bypass reaches matters).
//! The rate sweep fans out across cores through the unified sweep engine.
//!
//! ```bash
//! cargo run --release --example noc_traffic [pattern]
//! ```

use smart_pim::config::NocKind;
use smart_pim::noc::{run_synthetic, Mesh, Pattern, SyntheticConfig};
use smart_pim::sweep::{SweepRunner, SyntheticSweep};
use smart_pim::util::table::{fnum, Table};

fn main() {
    let pattern: Pattern = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "uniform_random".into())
        .parse()
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let mesh = Mesh::new(8, 8);

    // One parallel sweep over rates x {wormhole, smart, ideal}.
    let mut sweep = SyntheticSweep::new(mesh, 14);
    sweep.patterns = vec![pattern];
    sweep.rates = vec![0.02, 0.05, 0.1, 0.15, 0.2, 0.3, 0.5, 0.8];
    sweep.kinds = vec![NocKind::Wormhole, NocKind::Smart, NocKind::Ideal];
    sweep.per_point_seeds = false;
    let outcomes = sweep.run(&SweepRunner::new());

    let mut t = Table::new(
        format!("{} — latency (reception) vs injection rate", pattern.name()),
        &["rate", "wormhole", "smart", "ideal"],
    );
    for triple in sweep.rows_for(&outcomes, pattern).chunks(3) {
        let cell = |o: &smart_pim::sweep::SyntheticOutcome| {
            format!(
                "{} ({}){}",
                fnum(o.stats.avg_latency, 1),
                fnum(o.stats.reception_rate, 3),
                if o.stats.saturated() { " SAT" } else { "" }
            )
        };
        t.row(&[
            format!("{}", triple[0].rate),
            cell(triple[0]),
            cell(triple[1]),
            cell(triple[2]),
        ]);
    }
    t.print();

    // HPC_max ablation at a moderate load: the single-cycle multi-hop reach
    // is the mechanism behind SMART's latency win (Sec. V).
    let mut t = Table::new(
        "SMART HPC_max ablation (rate 0.1)",
        &["hpc_max", "avg latency", "net latency"],
    );
    for hpc in [1, 2, 4, 8, 14] {
        let cfg = SyntheticConfig {
            pattern,
            injection_rate: 0.1,
            ..Default::default()
        };
        let s = run_synthetic(NocKind::Smart, mesh, &cfg, hpc);
        t.row(&[
            format!("{hpc}"),
            fnum(s.avg_latency, 2),
            fnum(s.avg_net_latency, 2),
        ]);
    }
    t.print();
}
