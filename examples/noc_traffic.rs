//! NoC deep-dive: sweep a synthetic traffic pattern across injection rates
//! on the 8x8 mesh and print the Fig. 10/11 curves for wormhole vs SMART
//! vs ideal, plus an HPC_max ablation (how far the bypass reaches matters).
//!
//! ```bash
//! cargo run --release --example noc_traffic [pattern]
//! ```

use smart_pim::config::NocKind;
use smart_pim::noc::{run_synthetic, Mesh, Pattern, SyntheticConfig};
use smart_pim::util::table::{fnum, Table};

fn main() {
    let pattern: Pattern = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "uniform_random".into())
        .parse()
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let mesh = Mesh::new(8, 8);

    let mut t = Table::new(
        format!("{} — latency (reception) vs injection rate", pattern.name()),
        &["rate", "wormhole", "smart", "ideal"],
    );
    for rate in [0.02, 0.05, 0.1, 0.15, 0.2, 0.3, 0.5, 0.8] {
        let cfg = SyntheticConfig {
            pattern,
            injection_rate: rate,
            ..Default::default()
        };
        let cell = |kind| {
            let s = run_synthetic(kind, mesh, &cfg, 14);
            format!(
                "{} ({}){}",
                fnum(s.avg_latency, 1),
                fnum(s.reception_rate, 3),
                if s.saturated() { " SAT" } else { "" }
            )
        };
        t.row(&[
            format!("{rate}"),
            cell(NocKind::Wormhole),
            cell(NocKind::Smart),
            cell(NocKind::Ideal),
        ]);
    }
    t.print();

    // HPC_max ablation at a moderate load: the single-cycle multi-hop reach
    // is the mechanism behind SMART's latency win (Sec. V).
    let mut t = Table::new(
        "SMART HPC_max ablation (rate 0.1)",
        &["hpc_max", "avg latency", "net latency"],
    );
    for hpc in [1, 2, 4, 8, 14] {
        let cfg = SyntheticConfig {
            pattern,
            injection_rate: 0.1,
            ..Default::default()
        };
        let s = run_synthetic(NocKind::Smart, mesh, &cfg, hpc);
        t.row(&[
            format!("{hpc}"),
            fnum(s.avg_latency, 2),
            fnum(s.avg_net_latency, 2),
        ]);
    }
    t.print();
}
