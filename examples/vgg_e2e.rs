//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! 1. L1/L2 (build time): the quantized tiny-VGG whose every GEMM is the
//!    bit-serial ReRAM crossbar Pallas kernel, AOT-lowered to HLO text by
//!    `make artifacts`.
//! 2. L3 (this binary): the Rust coordinator loads the artifacts through
//!    PJRT, serves a batched synthetic image stream, and verifies outputs
//!    against the Python-side golden logits.
//! 3. The cycle-accurate simulator then projects the same workload class
//!    onto the paper's full-scale node (VGG-E @ 224x224), reporting the
//!    headline numbers next to the measured serving stats.
//!
//! ```bash
//! make artifacts && cargo run --release --example vgg_e2e
//! ```

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::coordinator::{BatchPolicy, Server};
use smart_pim::mapping::ReplicationPlan;
use smart_pim::runtime::vgg_tiny::{load_golden, IMAGE_LEN};
use smart_pim::runtime::Runtime;
use smart_pim::sim::{evaluate, evaluate_network};
use smart_pim::util::Rng;

fn main() {
    // ---- golden check: rust serving == python model, bit-for-bit-ish ----
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable: {e:#}");
            std::process::exit(1);
        }
    };
    let (img, want) = match load_golden(&rt, 1) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("artifacts missing ({e:#}) — run `make artifacts` first");
            std::process::exit(1);
        }
    };
    drop(rt);

    let mut server = Server::start("artifacts".into(), BatchPolicy::default())
        .expect("coordinator start");
    let resp = server.infer(img).expect("golden inference");
    let max_err = resp
        .logits
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!("golden check: max |rust - python| logit error = {max_err:.2e}");
    assert!(max_err < 1e-3, "golden mismatch");

    // ---- serve a stream of requests through the dynamic batcher ----
    let n = 32;
    let mut rng = Rng::new(2024);
    println!("serving {n} synthetic 32x32 images (quantized crossbar inference) ...");
    let pending: Vec<_> = (0..n)
        .map(|_| {
            let image: Vec<f32> = (0..IMAGE_LEN).map(|_| rng.next_f64() as f32).collect();
            server.submit(image)
        })
        .collect();
    let mut hist = [0u64; 10];
    for rx in pending {
        let resp = rx.recv().expect("worker alive").expect("inference ok");
        hist[resp.class] += 1;
    }
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches (batch-4: {}, batch-1: {})",
        stats.served, stats.batches, stats.batch_hist[4], stats.batch_hist[1]
    );
    println!(
        "measured: {:.2} req/s, latency p50 {:.0} ms / p99 {:.0} ms (interpret-mode kernel on CPU)",
        stats.throughput(),
        stats.latency_percentile_ms(50.0),
        stats.latency_percentile_ms(99.0)
    );
    println!("class histogram: {hist:?}");

    // ---- project the full-scale system with the cycle simulator ----
    println!();
    println!("cycle-accurate projection of the paper's node (VGG-E @ 224x224):");
    let arch = ArchConfig::paper_node();
    for (scenario, noc) in [
        (Scenario::Baseline, NocKind::Wormhole),
        (Scenario::ReplicationBatch, NocKind::Wormhole),
        (Scenario::ReplicationBatch, NocKind::Smart),
        (Scenario::ReplicationBatch, NocKind::Ideal),
    ] {
        let r = evaluate(VggVariant::E, scenario, noc, &arch);
        println!(
            "  scenario {} / {:<8}: {:>7.0} FPS  {:>8.4} TOPS  {:>7.4} TOPS/W",
            scenario.label(),
            noc.name(),
            r.fps,
            r.tops,
            r.tops_per_watt
        );
    }
    println!("  paper best case      :    1029 FPS   40.4027 TOPS   3.5914 TOPS/W");

    // ---- beyond the paper: a branching workload through the layer DAG ----
    println!();
    println!("layer-DAG projection (ResNet-18 @ 224x224, SMART NoC, batch pipelining):");
    let net = smart_pim::cnn::workload("resnet18").expect("resnet18 builds");
    let plans = [
        ("none", ReplicationPlan::none(&net)),
        (
            "searched",
            ReplicationPlan::searched(&net, &arch, 0).expect("searched plan fits the node"),
        ),
    ];
    for (label, plan) in plans {
        let r = evaluate_network(&net, &plan, true, NocKind::Smart, &arch, 8)
            .expect("resnet mapping fits");
        println!(
            "  plan {label:<9}: {:>7.0} FPS  {:>8.4} TOPS  {:>7.4} TOPS/W",
            r.fps, r.tops, r.tops_per_watt
        );
    }
}
