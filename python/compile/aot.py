"""AOT lowering: jax graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate links)
rejects (``proto.id() <= INT_MAX``); the HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written to --out-dir (default ../artifacts):

  crossbar_gemm_128.hlo.txt   single 128x128 subarray GEMM (microbench)
  vgg_tiny_b1.hlo.txt         tiny-VGG inference, batch 1
  vgg_tiny_b4.hlo.txt         tiny-VGG inference, batch 4
  weights_vgg_tiny.bin        int32 weight tensors for the runtime
  expected_logits_b{1,4}.txt  golden outputs for the Rust integration tests
  manifest.txt                one line per artifact: name, arity, shapes

Run via ``make artifacts`` (a no-op when inputs are unchanged).

Usage: python -m compile.aot [--out-dir DIR] [--seed N]
"""

from __future__ import annotations

import argparse
import functools
import os
import struct
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels.crossbar import crossbar_gemm_signed

WEIGHTS_MAGIC = 0x534D5057  # "SMPW"


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path: str, tensors: Sequence[np.ndarray], names: Sequence[str]) -> None:
    """Simple little-endian tensor container parsed by rust/src/runtime/weights.rs."""
    assert len(tensors) == len(names)
    with open(path, "wb") as f:
        f.write(struct.pack("<II", WEIGHTS_MAGIC, len(tensors)))
        for name, t in zip(names, tensors):
            t = np.ascontiguousarray(t.astype(np.int32))
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", t.ndim))
            f.write(struct.pack(f"<{t.ndim}I", *t.shape))
            f.write(t.tobytes())


def lower_crossbar_gemm() -> str:
    spec = jax.ShapeDtypeStruct((128, 128), jnp.int32)
    fn = functools.partial(crossbar_gemm_signed, adc_bits=model.DEFAULT_ADC_BITS)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_vgg_tiny(batch: int, weights: List[np.ndarray]) -> str:
    img_spec = jax.ShapeDtypeStruct(
        (batch, model.TINY_VGG.image_hw, model.TINY_VGG.image_hw, 3), jnp.float32
    )
    w_specs = [jax.ShapeDtypeStruct(w.shape, jnp.int32) for w in weights]

    def fn(image, *ws):
        return model.vgg_tiny_forward(image, ws)

    return to_hlo_text(jax.jit(fn).lower(img_spec, *w_specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-gemm", action="store_true", help="only the model artifacts")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    manifest: List[str] = []

    if not args.skip_gemm:
        text = lower_crossbar_gemm()
        _write(out, "crossbar_gemm_128.hlo.txt", text)
        manifest.append(
            "crossbar_gemm_128 inputs=i32[128,128],i32[128,128] output=i32[128,128]"
        )

    weights = model.init_weights(model.TINY_VGG, seed=args.seed)
    names = [f"w{i}" for i in range(len(weights))]
    write_weights_bin(os.path.join(out, "weights_vgg_tiny.bin"), weights, names)
    manifest.append(
        "weights_vgg_tiny tensors="
        + ",".join(f"{n}:{'x'.join(map(str, w.shape))}" for n, w in zip(names, weights))
    )

    for batch in (1, 4):
        text = lower_vgg_tiny(batch, weights)
        _write(out, f"vgg_tiny_b{batch}.hlo.txt", text)
        manifest.append(
            f"vgg_tiny_b{batch} inputs=f32[{batch},32,32,3]+{len(weights)}xweights "
            f"output=f32[{batch},10]"
        )
        # Golden outputs for the Rust integration tests.
        img = model.test_image(batch)
        logits = np.asarray(
            model.vgg_tiny_forward(jnp.asarray(img), [jnp.asarray(w) for w in weights])
        )
        lines = [" ".join(f"{v:.6f}" for v in row) for row in logits]
        _write(out, f"expected_logits_b{batch}.txt", "\n".join(lines) + "\n")
        img_lines = [" ".join(f"{v:.8f}" for v in row.reshape(-1)) for row in img]
        _write(out, f"test_image_b{batch}.txt", "\n".join(img_lines) + "\n")

    _write(out, "manifest.txt", "\n".join(manifest) + "\n")
    print(f"artifacts written to {out}")


def _write(out_dir: str, name: str, text: str) -> None:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}: {len(text)} chars")


if __name__ == "__main__":
    main()
