"""L2 — quantized CNN forward graph built on the L1 crossbar kernel.

This is the paper's compute graph written in JAX: every convolution and
fully-connected layer is lowered to an im2col GEMM executed by the bit-serial
ReRAM crossbar kernel (``kernels.crossbar``), with the same 16-bit activation
/ 16-bit weight quantization the architecture fixes (Sec. III). Pooling,
activation-requantization ("sigmoid unit" in the paper; we use ReLU as all
VGG variants do) and the final classifier head are digital and stay in jnp —
exactly like the tile-level shift&add / sigmoid / maxpool peripherals.

The paper's evaluation network is VGG A-E at 224x224; for the runnable
end-to-end artifact we use the same layer structure scaled to a tiny VGG on
32x32 (the full-scale networks are modeled cycle-accurately on the Rust
side — timing does not depend on pixel values). Weights are generated
deterministically from a seed and shipped to the Rust runtime through
``artifacts/weights_*.bin``; the HLO graph takes them as parameters so the
artifact stays small and the runtime exercises a realistic weight-loading
path.

Build-time only: nothing in this file is imported at serving time.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.crossbar import INPUT_BITS, SUBARRAY, crossbar_gemm, slice_weights

# ---------------------------------------------------------------------------
# Quantization parameters (paper: fixed 16-bit weights and feature maps).
# ---------------------------------------------------------------------------
ACT_FRAC_BITS = 8  # activations are unsigned Q8.8 fixed point
ACT_SCALE = float(1 << ACT_FRAC_BITS)
ACT_MAX = (1 << INPUT_BITS) - 1
WEIGHT_FRAC_BITS = 12  # weights are signed Q3.12
WEIGHT_SCALE = float(1 << WEIGHT_FRAC_BITS)
WEIGHT_MAX = (1 << 15) - 1
DEFAULT_ADC_BITS = 10  # lossless for a 128-row subarray (DESIGN.md §1)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One 3x3-conv (stride 1, SAME) + optional 2x2 maxpool stage."""

    in_ch: int
    out_ch: int
    pool: bool


@dataclasses.dataclass(frozen=True)
class TinyVggSpec:
    """VGG-style network scaled to a small input resolution."""

    image_hw: int
    convs: Tuple[ConvSpec, ...]
    fc_dims: Tuple[int, ...]  # hidden dims then classes

    @property
    def flat_dim(self) -> int:
        hw = self.image_hw
        for c in self.convs:
            if c.pool:
                hw //= 2
        return hw * hw * self.convs[-1].out_ch


TINY_VGG = TinyVggSpec(
    image_hw=32,
    convs=(
        ConvSpec(3, 16, pool=True),
        ConvSpec(16, 32, pool=True),
        ConvSpec(32, 32, pool=True),
    ),
    fc_dims=(64, 10),
)


def _round_up(x: int, to: int) -> int:
    return (x + to - 1) // to * to


# ---------------------------------------------------------------------------
# Weight generation (build-time, deterministic).
# ---------------------------------------------------------------------------
def init_weights(spec: TinyVggSpec, seed: int = 0) -> List[np.ndarray]:
    """He-initialized float weights quantized to signed int16 (as int32).

    Returns one (K, N) matrix per GEMM layer: convs first (K = in_ch*9,
    N = out_ch), then FCs. These are the arrays shipped to the Rust runtime.
    """
    rng = np.random.default_rng(seed)
    mats: List[np.ndarray] = []
    for c in spec.convs:
        k = c.in_ch * 9
        std = float(np.sqrt(2.0 / k))
        w = rng.normal(0.0, std, (k, c.out_ch))
        mats.append(_quantize_weights(w))
    in_dim = spec.flat_dim
    for out_dim in spec.fc_dims:
        std = float(np.sqrt(2.0 / in_dim))
        w = rng.normal(0.0, std, (in_dim, out_dim))
        mats.append(_quantize_weights(w))
        in_dim = out_dim
    return mats


def _quantize_weights(w: np.ndarray) -> np.ndarray:
    q = np.clip(np.round(w * WEIGHT_SCALE), -WEIGHT_MAX, WEIGHT_MAX)
    return q.astype(np.int32)


# ---------------------------------------------------------------------------
# Graph pieces.
# ---------------------------------------------------------------------------
def quantize_act(x: jax.Array) -> jax.Array:
    """Float activations -> unsigned Q8.8 int32 (the 16-bit IFM format)."""
    q = jnp.round(x * ACT_SCALE)
    return jnp.clip(q, 0, ACT_MAX).astype(jnp.int32)


def dequantize_acc(acc: jax.Array) -> jax.Array:
    """int32 GEMM accumulator -> float (activation x weight scales)."""
    return acc.astype(jnp.float32) / (ACT_SCALE * WEIGHT_SCALE)


def im2col(x: jax.Array, ksize: int = 3) -> jax.Array:
    """(B, H, W, C) -> (B*H*W, ksize*ksize*C) SAME-padded patches.

    Row-major kernel stride, matching Eq. (1)-(2)'s row-majored walk.
    """
    b, h, w, c = x.shape
    pad = ksize // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    patches = [
        xp[:, dy : dy + h, dx : dx + w, :]
        for dy in range(ksize)
        for dx in range(ksize)
    ]
    stacked = jnp.concatenate(patches, axis=-1)  # (B, H, W, k*k*C)
    return stacked.reshape(b * h * w, ksize * ksize * c)


def crossbar_matmul(
    x_q: jax.Array,
    w_q: jax.Array,
    *,
    adc_bits: int = DEFAULT_ADC_BITS,
) -> jax.Array:
    """Pad (M, K) x (K, N) to subarray multiples and run the Pallas kernel.

    Zero-padding is exact under the biased-cell encoding: padded activation
    rows contribute no charge and no bias counts; padded weight columns decode
    to exactly zero after bias correction.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, f"GEMM mismatch ({m},{k}) x ({k2},{n})"
    mp = _round_up(m, SUBARRAY)
    kp = _round_up(k, SUBARRAY)
    np_ = _round_up(n, SUBARRAY)
    xpad = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    wpad = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    out = crossbar_gemm(xpad, slice_weights(wpad), adc_bits=adc_bits)
    return out[:m, :n]


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max pooling on (B, H, W, C) — the tile's MP unit."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def vgg_tiny_forward(
    image: jax.Array,
    weights: Sequence[jax.Array],
    *,
    spec: TinyVggSpec = TINY_VGG,
    adc_bits: int = DEFAULT_ADC_BITS,
) -> jax.Array:
    """Quantized tiny-VGG inference: (B, 32, 32, 3) float in [0,1] -> logits.

    Every GEMM goes through the bit-serial crossbar kernel; inter-layer
    requantization reproduces the IR/OR + shift&add digital path.
    """
    b = image.shape[0]
    x = jnp.clip(image, 0.0, 1.0)
    hw = spec.image_hw
    n_conv = len(spec.convs)
    for i, c in enumerate(spec.convs):
        x_q = quantize_act(x)  # (B, hw, hw, in_ch) uint16-valued
        cols = im2col(x_q)  # (B*hw*hw, in_ch*9)
        acc = crossbar_matmul(cols, weights[i], adc_bits=adc_bits)
        y = dequantize_acc(acc).reshape(b, hw, hw, c.out_ch)
        y = jax.nn.relu(y)
        if c.pool:
            y = maxpool2(y)
            hw //= 2
        x = y
    x = x.reshape(b, -1)  # (B, flat_dim)
    for j, out_dim in enumerate(spec.fc_dims):
        x_q = quantize_act(x)
        acc = crossbar_matmul(x_q, weights[n_conv + j], adc_bits=adc_bits)
        x = dequantize_acc(acc)
        if j + 1 < len(spec.fc_dims):
            x = jax.nn.relu(x)
    return x  # (B, classes) float logits


def vgg_tiny_forward_float(
    image: jax.Array,
    weights: Sequence[jax.Array],
    *,
    spec: TinyVggSpec = TINY_VGG,
) -> jax.Array:
    """Float reference of the same network (dequantized weights, exact conv).

    Used by pytest to bound the quantization error of the crossbar path.
    """
    b = image.shape[0]
    x = jnp.clip(image, 0.0, 1.0)
    hw = spec.image_hw
    n_conv = len(spec.convs)
    for i, c in enumerate(spec.convs):
        wf = weights[i].astype(jnp.float32) / WEIGHT_SCALE
        cols = im2col(x)
        y = (cols @ wf).reshape(b, hw, hw, c.out_ch)
        y = jax.nn.relu(y)
        if c.pool:
            y = maxpool2(y)
            hw //= 2
        x = y
    x = x.reshape(b, -1)
    for j, _ in enumerate(spec.fc_dims):
        wf = weights[n_conv + j].astype(jnp.float32) / WEIGHT_SCALE
        x = x @ wf
        if j + 1 < len(spec.fc_dims):
            x = jax.nn.relu(x)
    return x


def test_image(batch: int, seed: int = 7) -> np.ndarray:
    """Deterministic synthetic image batch in [0, 1] (B, 32, 32, 3)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(
        0.0, 1.0, (batch, TINY_VGG.image_hw, TINY_VGG.image_hw, 3)
    ).astype(np.float32)
