"""L1 — Pallas kernel: bit-serial ReRAM crossbar GEMM.

Functional model of the paper's analog compute path (Sec. II-C / III):

  1-bit DACs stream the 16-bit activation in 16 bit-phases onto the word
  lines; each weight is stored as 8 x 2-bit MLC cells across 8 adjacent bit
  lines; the analog column current (a Kirchhoff sum) is sampled, converted by
  an 8-bit ADC, and the per-phase / per-slice partial sums are recombined by
  the shift & add units.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the 128x128 subarray maps
onto a 128x128 MXU-aligned block; the bit-serial DAC becomes a loop over bit
planes (each plane is a {0,1} matrix x cell matrix product — exactly what the
array computes in one phase); VMEM holds one weight block + one activation
stripe per grid step, mirroring the eDRAM input register staging.

Signed weights use the ISAAC-style bias trick (Sec. II-D): weights are stored
biased by +2**15 as unsigned 16-bit, and the bias is subtracted digitally
using the per-plane row-sums of the activation bits (which the hardware gets
for free from a dedicated always-on column).

Everything is integer-exact; ADC saturation is the only lossy step, and it is
configurable (`adc_bits`). With adc_bits >= ceil(log2(rows*3)) + 1 the kernel
is bit-exact equal to the plain int GEMM (property-tested in
python/tests/test_kernel.py).

The kernel MUST run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed by the paper's architecture (Sec. III).
INPUT_BITS = 16  # 16-bit IFM, streamed 1 bit/phase through 1-bit DACs
CELL_BITS = 2  # 2-bit MLC ReRAM cells
N_SLICES = 8  # 16-bit weight = 8 x 2-bit cells across 8 columns
WEIGHT_BIAS = 1 << 15  # ISAAC-style bias for signed weights
SUBARRAY = 128  # 128x128 crossbar subarray == MXU tile


def slice_weights(w: jax.Array) -> jax.Array:
    """Slice signed int weights (K, N) into biased 2-bit cells (K, N*8).

    Cell layout: column-major slices — cells of output column n occupy
    columns [n*8, n*8+8) of the returned matrix, least-significant slice
    first, exactly like the paper's "eight cells across eight different
    columns".
    """
    wb = (w.astype(jnp.int32) + WEIGHT_BIAS).astype(jnp.uint32)  # unsigned 16-bit
    shifts = jnp.arange(N_SLICES, dtype=jnp.uint32) * CELL_BITS  # (8,)
    cells = (wb[:, :, None] >> shifts[None, None, :]) & 0x3  # (K, N, 8)
    k, n = w.shape
    return cells.astype(jnp.int32).reshape(k, n * N_SLICES)


def _crossbar_kernel(x_ref, wc_ref, o_ref, *, adc_bits: int, input_bits: int):
    """One grid step: (bm, bk) activation block x (bk, bn*8) cell block.

    Grid is (M/bm, N/bn, K/bk); K is the innermost (fastest) dimension so the
    output block accumulates across K steps (subarrays stacked over the
    reduction dimension, recombined by the tile-level shift & add).
    """
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.uint32)  # (bm, bk) unsigned activations
    wc = wc_ref[...]  # (bk, bn*8) int32 cells in 0..3
    bm, bk = x.shape
    bn8 = wc.shape[1]
    adc_max = (1 << adc_bits) - 1

    acc = jnp.zeros((bm, bn8 // N_SLICES), jnp.int32)
    bias_acc = jnp.zeros((bm, 1), jnp.int32)
    # Bit-serial phases: one {0,1} plane per clock through the 1-bit DACs.
    for b in range(input_bits):
        plane = ((x >> b) & 1).astype(jnp.int32)  # (bm, bk)
        # Analog column currents for all 8 slices at once (Kirchhoff sum),
        # then the ADC clips each column sample to its dynamic range.
        col = jax.lax.dot_general(
            plane, wc, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (bm, bn*8)
        col = jnp.minimum(col, adc_max)
        # Shift & add: recombine the 8 cell slices (x4 each) and the input
        # bit weight (x2 each phase).
        sliced = col.reshape(bm, bn8 // N_SLICES, N_SLICES)
        shifts = (1 << (CELL_BITS * jnp.arange(N_SLICES, dtype=jnp.int32)))
        acc += (sliced * shifts[None, None, :]).sum(axis=2) << b
        # Row-sum of the plane = the always-on bias column sample.
        bias_acc += plane.sum(axis=1, keepdims=True) << b
    # Digital bias correction: y = y_biased - 2^15 * sum_i a_i.
    o_ref[...] += acc - bias_acc * WEIGHT_BIAS


@functools.partial(
    jax.jit,
    static_argnames=("adc_bits", "input_bits", "block_m", "block_n", "block_k"),
)
def crossbar_gemm(
    x: jax.Array,
    w_cells: jax.Array,
    *,
    adc_bits: int = 10,
    input_bits: int = INPUT_BITS,
    block_m: int = SUBARRAY,
    block_n: int = SUBARRAY,
    block_k: int = SUBARRAY,
) -> jax.Array:
    """Bit-serial crossbar GEMM: (M, K) uint activations x pre-sliced cells.

    Args:
      x: (M, K) int32, values in [0, 2**input_bits) — unsigned fixed-point
        IFM (post-ReLU activations are non-negative).
      w_cells: (K, N*8) int32 cells in 0..3 from :func:`slice_weights`.
      adc_bits: ADC resolution; sums are clipped to 2**adc_bits - 1. The
        paper's array (128 rows, 1-bit input, 2-bit cells) needs 10 bits to
        be lossless; 8 saturates on dense high inputs (fidelity experiments).
      input_bits: DAC phases (16 in the paper).
      block_m/n/k: VMEM block shape; 128 matches subarray == MXU tile.

    Returns:
      (M, N) int32 — exact signed GEMM result when the ADC does not clip.
    """
    m, k = x.shape
    k2, n8 = w_cells.shape
    assert k == k2, f"reduction mismatch {k} vs {k2}"
    assert n8 % N_SLICES == 0
    n = n8 // N_SLICES
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k})x({k},{n}) must tile by ({block_m},{block_k},{block_n})"
    )

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(
            _crossbar_kernel, adc_bits=adc_bits, input_bits=input_bits
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n * N_SLICES), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w_cells)


def crossbar_gemm_signed(
    x: jax.Array, w: jax.Array, **kw
) -> jax.Array:
    """Convenience wrapper: slices signed weights then runs the kernel."""
    return crossbar_gemm(x, slice_weights(w), **kw)
