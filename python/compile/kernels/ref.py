"""Pure-jnp oracle for the crossbar kernel — the CORE correctness signal.

Implements exactly the same bit-serial / cell-sliced / ADC-clipped math as
``crossbar.py`` but with straight-line jnp (no pallas, no blocking), plus the
trivially-correct exact integer GEMM the lossless configuration must equal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .crossbar import (
    CELL_BITS,
    N_SLICES,
    WEIGHT_BIAS,
    slice_weights,
)


def exact_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain signed integer GEMM — what a lossless crossbar must compute."""
    return jax.lax.dot_general(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def crossbar_gemm_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    adc_bits: int = 10,
    input_bits: int = 16,
) -> jax.Array:
    """Reference bit-serial crossbar GEMM over signed weights (K, N).

    Mirrors the analog path step by step: bias the weights, slice into 2-bit
    cells, stream input bit-planes, clip each per-phase/per-slice column sum
    to the ADC range, shift & add, subtract the digital bias term.
    """
    m, k = x.shape
    _, n = w.shape
    cells = slice_weights(w).reshape(k, n, N_SLICES)  # (K, N, 8) in 0..3
    xu = x.astype(jnp.uint32)
    adc_max = (1 << adc_bits) - 1

    out = jnp.zeros((m, n), jnp.int32)
    bias = jnp.zeros((m, 1), jnp.int32)
    for b in range(input_bits):
        plane = ((xu >> b) & 1).astype(jnp.int32)  # (M, K)
        # per-slice analog column sums, one ADC sample each
        col = jnp.einsum("mk,kns->mns", plane, cells)
        col = jnp.minimum(col, adc_max)
        shifts = 1 << (CELL_BITS * jnp.arange(N_SLICES, dtype=jnp.int32))
        out = out + ((col * shifts[None, None, :]).sum(axis=2) << b)
        bias = bias + (plane.sum(axis=1, keepdims=True) << b)
    return out - bias * WEIGHT_BIAS
