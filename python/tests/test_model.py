"""L2 correctness: the quantized tiny-VGG graph (crossbar-kernel GEMMs)
against its float reference, plus shape and padding invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import exact_gemm

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="module")
def weights():
    return [jnp.asarray(w) for w in model.init_weights(model.TINY_VGG, seed=0)]


class TestQuantization:
    def test_act_quant_range(self):
        x = jnp.asarray([[-1.0, 0.0, 0.5, 1.0, 300.0]])
        q = np.asarray(model.quantize_act(x))
        assert q[0, 0] == 0  # clipped below
        assert q[0, 1] == 0
        assert q[0, 2] == 128  # 0.5 * 256
        assert q[0, 3] == 256
        assert q[0, 4] == model.ACT_MAX  # clipped above

    def test_weight_quant_symmetric(self):
        w = model._quantize_weights(np.asarray([[1.0, -1.0]]))
        assert w[0, 0] == 1 << model.WEIGHT_FRAC_BITS
        assert w[0, 1] == -(1 << model.WEIGHT_FRAC_BITS)

    @given(seed=st.integers(0, 2**31))
    def test_dequant_inverts_scales(self, seed):
        rng = np.random.default_rng(seed)
        acc = jnp.asarray(rng.integers(-(1 << 24), 1 << 24, (3, 3)), jnp.int32)
        f = np.asarray(model.dequantize_acc(acc))
        np.testing.assert_allclose(
            f, np.asarray(acc) / (model.ACT_SCALE * model.WEIGHT_SCALE), rtol=1e-6
        )


class TestIm2col:
    def test_shape(self):
        x = jnp.zeros((2, 8, 8, 3))
        cols = model.im2col(x)
        assert cols.shape == (2 * 64, 27)

    def test_center_pixel_identity(self):
        # With a delta kernel the center column reproduces the input.
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.uniform(size=(1, 5, 5, 2)), jnp.float32)
        cols = model.im2col(x)
        # patch layout: (dy, dx) majors, channels minor; center = (1,1)
        center = np.asarray(cols).reshape(25, 9, 2)[:, 4, :]
        np.testing.assert_allclose(center, np.asarray(x).reshape(25, 2))

    def test_padding_zeros_at_corner(self):
        x = jnp.ones((1, 4, 4, 1))
        cols = np.asarray(model.im2col(x)).reshape(16, 9)
        # top-left output pixel: the (0,0) tap comes from SAME padding
        assert cols[0, 0] == 0.0
        assert cols[0, 4] == 1.0


class TestCrossbarMatmul:
    @given(
        m=st.integers(1, 9),
        k=st.integers(1, 40),
        n=st.integers(1, 9),
        seed=st.integers(0, 2**31),
    )
    def test_padded_gemm_exact(self, m, k, n, seed):
        # crossbar_matmul pads to 128-multiples; padding must be exact.
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(0, 1 << 16, (m, k)), jnp.int32)
        w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, (k, n)), jnp.int32)
        got = model.crossbar_matmul(x, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exact_gemm(x, w)))


class TestTinyVgg:
    def test_logit_shapes(self, weights):
        img = jnp.asarray(model.test_image(2))
        logits = model.vgg_tiny_forward(img, weights)
        assert logits.shape == (2, 10)

    def test_quantized_close_to_float(self, weights):
        img = jnp.asarray(model.test_image(1))
        q = model.vgg_tiny_forward(img, weights)
        f = model.vgg_tiny_forward_float(img, weights)
        err = float(jnp.abs(q - f).max())
        assert err < 0.05, f"quantization error {err}"

    def test_batch_elements_independent(self, weights):
        imgs = model.test_image(4)
        batched = np.asarray(model.vgg_tiny_forward(jnp.asarray(imgs), weights))
        single = np.asarray(
            model.vgg_tiny_forward(jnp.asarray(imgs[2:3]), weights)
        )
        np.testing.assert_allclose(batched[2:3], single, atol=1e-5)

    def test_deterministic(self, weights):
        img = jnp.asarray(model.test_image(1))
        a = np.asarray(model.vgg_tiny_forward(img, weights))
        b = np.asarray(model.vgg_tiny_forward(img, weights))
        np.testing.assert_array_equal(a, b)

    def test_flat_dim_matches_weights(self):
        spec = model.TINY_VGG
        ws = model.init_weights(spec)
        assert ws[len(spec.convs)].shape[0] == spec.flat_dim
        assert ws[-1].shape[1] == spec.fc_dims[-1]

    def test_jit_lowerable(self, weights):
        # The exact graph aot.py lowers must trace without concrete inputs.
        img_spec = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
        w_specs = [jax.ShapeDtypeStruct(w.shape, jnp.int32) for w in weights]

        def fn(image, *ws):
            return model.vgg_tiny_forward(image, ws)

        lowered = jax.jit(fn).lower(img_spec, *w_specs)
        assert "xla" in str(type(lowered)).lower() or lowered is not None
