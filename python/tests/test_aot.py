"""AOT path: HLO-text emission and the weights container format."""

import os
import struct

import numpy as np
import pytest

from compile import aot, model


class TestWeightsBin:
    def test_container_round_trip(self, tmp_path):
        tensors = [
            np.arange(6, dtype=np.int32).reshape(2, 3),
            np.asarray([-7], dtype=np.int32),
        ]
        path = tmp_path / "w.bin"
        aot.write_weights_bin(str(path), tensors, ["a", "b"])
        raw = path.read_bytes()
        magic, count = struct.unpack_from("<II", raw, 0)
        assert magic == aot.WEIGHTS_MAGIC
        assert count == 2
        # parse manually
        off = 8
        for want in tensors:
            (nlen,) = struct.unpack_from("<I", raw, off)
            off += 4 + nlen
            (ndim,) = struct.unpack_from("<I", raw, off)
            off += 4
            dims = struct.unpack_from(f"<{ndim}I", raw, off)
            off += 4 * ndim
            n = int(np.prod(dims))
            data = np.frombuffer(raw, dtype="<i4", count=n, offset=off)
            off += 4 * n
            np.testing.assert_array_equal(data.reshape(dims), want)
        assert off == len(raw)


class TestHloText:
    def test_gemm_lowering_is_hlo_text(self):
        text = aot.lower_crossbar_gemm()
        assert "HloModule" in text
        assert "ENTRY" in text
        # int32 128x128 params visible in the signature
        assert "s32[128,128]" in text

    @pytest.mark.slow
    def test_model_lowering_contains_loops(self):
        ws = model.init_weights(model.TINY_VGG, seed=0)
        text = aot.lower_vgg_tiny(1, ws)
        assert "HloModule" in text
        assert "f32[1,10]" in text  # logits signature


class TestArtifactsDir:
    """Checks over the committed artifacts when present (post `make
    artifacts`); skipped otherwise so the suite runs pre-build too."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    def _need(self, name):
        path = os.path.join(self.ART, name)
        if not os.path.exists(path):
            pytest.skip(f"{name} absent — run `make artifacts`")
        return path

    def test_manifest_lists_artifacts(self):
        path = self._need("manifest.txt")
        text = open(path).read()
        for key in ("crossbar_gemm_128", "vgg_tiny_b1", "vgg_tiny_b4", "weights_vgg_tiny"):
            assert key in text, f"{key} missing from manifest"

    def test_expected_logits_match_model(self):
        self._need("expected_logits_b1.txt")
        import jax.numpy as jnp

        ws = [jnp.asarray(w) for w in model.init_weights(model.TINY_VGG, seed=0)]
        img = jnp.asarray(model.test_image(1))
        got = np.asarray(model.vgg_tiny_forward(img, ws))[0]
        want = np.loadtxt(os.path.join(self.ART, "expected_logits_b1.txt"))
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_hlo_artifacts_parse_as_text(self):
        for name in ("crossbar_gemm_128.hlo.txt", "vgg_tiny_b1.hlo.txt"):
            path = self._need(name)
            head = open(path).read(200)
            assert head.startswith("HloModule"), f"{name} is not HLO text"
