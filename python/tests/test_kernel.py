"""L1 correctness: the Pallas crossbar kernel vs the pure-jnp oracle —
the CORE correctness signal of the build (DESIGN.md §6).

hypothesis sweeps shapes, bit-widths, signs and block shapes; every case
must be bit-exact against ref.py, and the lossless configuration must equal
the plain integer GEMM.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.crossbar import (
    N_SLICES,
    SUBARRAY,
    crossbar_gemm,
    crossbar_gemm_signed,
    slice_weights,
)
from compile.kernels.ref import crossbar_gemm_ref, exact_gemm

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand_case(rng, m, k, n, x_max=1 << 16, w_max=1 << 15):
    x = jnp.asarray(rng.integers(0, x_max, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(-w_max, w_max, (k, n)), jnp.int32)
    return x, w


class TestSliceWeights:
    def test_cells_in_range(self):
        rng = np.random.default_rng(0)
        _, w = rand_case(rng, 1, 16, 8)
        cells = np.asarray(slice_weights(w))
        assert cells.min() >= 0 and cells.max() <= 3
        assert cells.shape == (16, 8 * N_SLICES)

    def test_cells_decode_back(self):
        rng = np.random.default_rng(1)
        _, w = rand_case(rng, 1, 8, 4)
        cells = np.asarray(slice_weights(w)).reshape(8, 4, N_SLICES)
        shifts = 4 ** np.arange(N_SLICES)
        decoded = (cells * shifts).sum(axis=2) - (1 << 15)
        np.testing.assert_array_equal(decoded, np.asarray(w))

    @given(st.integers(-(1 << 15), (1 << 15) - 1))
    def test_single_weight_round_trip(self, w):
        wa = jnp.asarray([[w]], jnp.int32)
        cells = np.asarray(slice_weights(wa)).reshape(N_SLICES)
        val = sum(int(c) << (2 * i) for i, c in enumerate(cells)) - (1 << 15)
        assert val == w


class TestRefOracle:
    """The oracle itself must equal the exact GEMM when lossless."""

    @given(
        m=st.integers(1, 6),
        k=st.integers(1, 40),
        n=st.integers(1, 6),
        seed=st.integers(0, 2**31),
    )
    def test_lossless_equals_exact(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w = rand_case(rng, m, k, n)
        # adc wide enough for k rows of 1-bit x 2-bit products
        adc = max(2, int(np.ceil(np.log2(k * 3 + 1))))
        got = crossbar_gemm_ref(x, w, adc_bits=adc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exact_gemm(x, w)))

    def test_adc_clipping_bites_on_dense_input(self):
        # All-ones 16-bit input with max-positive weights must clip at 8 bits.
        k = 128
        x = jnp.full((1, k), (1 << 16) - 1, jnp.int32)
        w = jnp.full((k, 1), (1 << 15) - 1, jnp.int32)
        lossless = crossbar_gemm_ref(x, w, adc_bits=10)
        clipped = crossbar_gemm_ref(x, w, adc_bits=8)
        np.testing.assert_array_equal(
            np.asarray(lossless), np.asarray(exact_gemm(x, w))
        )
        assert np.all(np.asarray(clipped) != np.asarray(lossless))

    def test_clipping_monotone_in_adc_bits(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.integers(1 << 15, 1 << 16, (2, 64)), jnp.int32)
        w = jnp.asarray(rng.integers(1 << 13, 1 << 15, (64, 2)), jnp.int32)
        errs = []
        for adc in (6, 7, 8, 9, 10):
            got = np.asarray(crossbar_gemm_ref(x, w, adc_bits=adc))
            errs.append(np.abs(got - np.asarray(exact_gemm(x, w))).max())
        assert all(a >= b for a, b in zip(errs, errs[1:])), errs
        assert errs[-1] == 0


class TestPallasKernel:
    """The Pallas kernel must be bit-exact against the oracle."""

    @pytest.mark.parametrize("adc_bits", [8, 10])
    def test_subarray_shape_exact(self, adc_bits):
        rng = np.random.default_rng(7)
        x, w = rand_case(rng, SUBARRAY, SUBARRAY, SUBARRAY)
        got = crossbar_gemm_signed(x, w, adc_bits=adc_bits)
        want = crossbar_gemm_ref(x, w, adc_bits=adc_bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        mb=st.integers(1, 2),
        kb=st.integers(1, 2),
        nb=st.integers(1, 2),
        block=st.sampled_from([8, 16, 32]),
        adc_bits=st.sampled_from([6, 8, 10]),
        seed=st.integers(0, 2**31),
    )
    def test_blocked_shapes_vs_ref(self, mb, kb, nb, block, adc_bits, seed):
        rng = np.random.default_rng(seed)
        m, k, n = mb * block, kb * block, nb * block
        x, w = rand_case(rng, m, k, n)
        got = crossbar_gemm(
            x,
            slice_weights(w),
            adc_bits=adc_bits,
            block_m=block,
            block_k=block,
            block_n=block,
        )
        want = crossbar_gemm_ref(x, w, adc_bits=adc_bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        input_bits=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31),
    )
    def test_reduced_input_bits(self, input_bits, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(0, 1 << input_bits, (8, 16)), jnp.int32)
        w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, (16, 8)), jnp.int32)
        got = crossbar_gemm(
            x,
            slice_weights(w),
            adc_bits=10,
            input_bits=input_bits,
            block_m=8,
            block_k=16,
            block_n=8,
        )
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(exact_gemm(x, w))
        )

    def test_zero_input_zero_output(self):
        x = jnp.zeros((8, 8), jnp.int32)
        w = jnp.asarray(
            np.random.default_rng(0).integers(-100, 100, (8, 8)), jnp.int32
        )
        got = crossbar_gemm_signed(x, w, adc_bits=10, block_m=8, block_k=8, block_n=8)
        assert np.all(np.asarray(got) == 0)

    def test_zero_weights_zero_output(self):
        # Padding exactness: zero weights decode to exactly zero despite the
        # biased cell encoding.
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.integers(0, 1 << 16, (8, 8)), jnp.int32)
        w = jnp.zeros((8, 8), jnp.int32)
        got = crossbar_gemm_signed(x, w, adc_bits=10, block_m=8, block_k=8, block_n=8)
        assert np.all(np.asarray(got) == 0)

    def test_shape_mismatch_raises(self):
        x = jnp.zeros((8, 9), jnp.int32)
        w = jnp.zeros((8, 8), jnp.int32)
        with pytest.raises(AssertionError):
            crossbar_gemm_signed(x, w, block_m=8, block_k=8, block_n=8)

    def test_non_divisible_block_raises(self):
        x = jnp.zeros((8, 8), jnp.int32)
        w = jnp.zeros((8, 8), jnp.int32)
        with pytest.raises(AssertionError):
            crossbar_gemm_signed(x, w, block_m=16, block_k=16, block_n=16)
